"""Placement runtime: policy registry, migration executor, domain arbiter,
telemetry, pool rebalancing, and the two-stage co-scheduled search."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import interleave
from repro.core.dwp import CoScheduledTuner, DWPConfig
from repro.placement import policy as pol
from repro.placement.arbiter import DomainArbiter, DomainSpec, Priority
from repro.placement.executor import MigrationExecutor
from repro.placement.telemetry import DomainTelemetry, Ring
from repro.serve.kvcache import BwapPagePool, MemoryDomain


@pytest.fixture(scope="module")
def small_cfg():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    return dataclasses.replace(cfg, num_layers=1, compute_dtype="float32")


def _ctx(bws=(819.0, 50.0, 16.0), pages=1000, workers=(0,), dwp=0.0,
         caps=None):
    return pol.PlacementContext(
        bandwidths=np.asarray(bws), num_pages=pages, workers=workers,
        dwp=dwp, capacities=None if caps is None else np.asarray(caps))


def _pool(cfg, pages=64, page_size=4, **kw):
    domains = [
        MemoryDomain("hbm_local", pages // 2, 819.0, True),
        MemoryDomain("hbm_peer", pages // 4, 50.0, False),
        MemoryDomain("host", pages - pages // 2 - pages // 4, 16.0, False),
    ]
    return BwapPagePool(cfg, domains, page_size=page_size,
                        dwp_config=DWPConfig(n=4, c=1), **kw)


# -- policy registry ----------------------------------------------------------

def test_registry_has_all_four_policies():
    assert {"uniform", "bwap_canonical", "bwap_dwp",
            "local_first"} <= set(pol.available())
    with pytest.raises(KeyError):
        pol.get("no_such_policy")


def test_uniform_weights_are_equal():
    w = pol.weights("uniform", _ctx())
    np.testing.assert_allclose(w, 1 / 3)


def test_canonical_weights_proportional_to_bw():
    w = pol.weights("bwap_canonical", _ctx())
    bw = np.asarray([819.0, 50.0, 16.0])
    np.testing.assert_allclose(w, bw / bw.sum())


def test_bwap_dwp_matches_core_dwp_weights():
    ctx = _ctx(dwp=0.4)
    w = pol.weights("bwap_dwp", ctx)
    canon = interleave.normalize(np.asarray([819.0, 50.0, 16.0]))
    np.testing.assert_allclose(w, interleave.dwp_weights(canon, [0], 0.4))


def test_local_first_fills_fastest_then_spills():
    c = pol.get("local_first").counts(_ctx(pages=150, caps=(100, 100, 100)))
    np.testing.assert_array_equal(c, [100, 50, 0])


def test_counts_respect_capacity_and_total():
    ctx = _ctx(pages=1000, caps=(100, 600, 600))
    for name in pol.available():
        c = pol.get(name).counts(ctx)
        assert int(c.sum()) == 1000, name
        assert (c <= np.asarray([100, 600, 600])).all(), name


def test_counts_raise_when_capacity_exceeded():
    ctx = _ctx(pages=1000, caps=(100, 100, 100))
    for name in pol.available():
        with pytest.raises(ValueError):
            pol.get(name).counts(ctx)


def test_assign_fractions_follow_clamped_counts():
    ctx = _ctx(pages=1024, caps=(64, 2000, 2000))
    a = pol.assign("bwap_canonical", ctx)
    counts = np.bincount(a, minlength=3)
    assert counts[0] <= 64
    assert counts.sum() == 1024
    # overflow spilled toward the faster of the remaining domains
    assert counts[1] > counts[2]


# -- migration executor -------------------------------------------------------

def test_executor_matches_per_page_oracle():
    k = jnp.arange(2 * 16 * 3 * 2 * 4, dtype=jnp.float32).reshape(
        2, 16, 3, 2, 4)
    v = k * 2.0
    src = [0, 3, 5, 7]
    dst = [8, 9, 12, 15]
    ex = MigrationExecutor()
    (bk, bv), res = ex.execute((k, v), src, dst)
    (lk, lv), _ = ex.execute_looped((k, v), src, dst)
    assert jnp.array_equal(bk, lk) and jnp.array_equal(bv, lv)
    assert res.num_moves == 4
    # 2 arrays x 4 pages x (page bytes of one array)
    page_bytes = 2 * 3 * 2 * 4 * 4
    assert res.bytes_moved == 2 * 4 * page_bytes


def test_executor_empty_moves_is_noop():
    k = jnp.ones((1, 4, 2))
    ex = MigrationExecutor()
    (out,), res = ex.execute((k,), [], [])
    assert out is k and res.num_moves == 0


def test_executor_copy_across_pools():
    src_arr = jnp.arange(1 * 8 * 2, dtype=jnp.float32).reshape(1, 8, 2)
    dst_arr = jnp.zeros((1, 12, 2), jnp.float32)
    ex = MigrationExecutor()
    (out,), res = ex.copy((src_arr,), (dst_arr,), [1, 7], [0, 11])
    assert jnp.array_equal(out[:, 0], src_arr[:, 1])
    assert jnp.array_equal(out[:, 11], src_arr[:, 7])
    assert res.num_moves == 2


def test_executor_records_pair_telemetry():
    tel = DomainTelemetry(["a", "b"])
    ex = MigrationExecutor(telemetry=tel)
    k = jnp.ones((1, 8, 2))
    ex.execute((k,), [0, 1, 2], [4, 5, 6],
               src_domains=[0, 0, 0], dst_domains=[1, 1, 1])
    assert tel.migrations_out[0] == 3
    assert tel.migrations_in[1] == 3
    assert tel.bytes_moved > 0


# -- telemetry ----------------------------------------------------------------

def test_ring_overwrites_oldest():
    r = Ring(capacity=3)
    for x in [1.0, 2.0, 3.0, 4.0]:
        r.push(x)
    np.testing.assert_array_equal(r.values(), [2.0, 3.0, 4.0])
    assert r.last() == 4.0
    assert len(r) == 3


def test_telemetry_snapshot_counters():
    t = DomainTelemetry(["fast", "slow"], ring_capacity=8)
    t.record_alloc(0, 3)
    t.record_free(0, 1)
    t.record_migration(0, 1, pages=2, nbytes=256)
    t.record_latency(0.5)
    t.record_stall(1, 0.1)
    s = t.snapshot()
    assert s["domains"]["fast"]["allocs"] == 3
    assert s["domains"]["fast"]["migr_out"] == 2
    assert s["domains"]["slow"]["migr_in"] == 2
    assert s["domains"]["slow"]["bytes_in"] == 256
    assert s["latency_last_s"] == 0.5
    assert s["executed_moves"] == 2


# -- pool on the new runtime --------------------------------------------------

def test_pool_migrate_sequence_is_batched_and_conserves_pages(small_cfg):
    pool = _pool(small_cfg, pages=64)
    ids = [pool.alloc_page() for _ in range(12)]
    # stamp each page so we can track the physical copy
    for pid in ids:
        pool.k_pool = pool.k_pool.at[:, pid].set(float(pid))
    before_total = sum(len(f) for f in pool.free)
    # force a strong worker shift so migration actually moves pages
    pool.tuner.dwp = 1.0
    new_ids = pool.migrate_sequence(ids)
    assert len(new_ids) == len(ids)
    assert sum(len(f) for f in pool.free) == before_total
    for old, new in zip(ids, new_ids):
        np.testing.assert_allclose(np.asarray(pool.k_pool[:, new]),
                                   float(old))
    moved = sum(1 for o, n in zip(ids, new_ids) if o != n)
    tel = pool.telemetry.snapshot()
    assert tel["executed_moves"] == moved > 0


def test_pool_alloc_fallback_uses_precomputed_bw_order(small_cfg):
    pool = _pool(small_cfg, pages=16)
    assert pool._bw_order[0] == 0                    # fastest domain first
    # drain the worker domain; allocation must fall back by bandwidth order
    pool.free[0] = []
    pid = pool.alloc_page()
    assert pool.domain_of(pid) in (1, 2)


def test_pool_rebalance_grows_capacity_and_remaps(small_cfg):
    pool = _pool(small_cfg, pages=32)
    ids = [pool.alloc_page() for _ in range(10)]
    for pid in ids:
        pool.k_pool = pool.k_pool.at[:, pid].set(float(pid) + 1.0)
    id_map = pool.rebalance([24, 12, 12])
    assert pool.total_pages == 48
    assert [d.num_pages for d in pool.domains] == [24, 12, 12]
    for old in ids:
        new = int(id_map[old])
        assert new >= 0
        np.testing.assert_allclose(np.asarray(pool.k_pool[:, new]),
                                   float(old) + 1.0)
    live = sum(len(p) for p in pool.live_pages())
    assert live == 10
    assert sum(len(f) for f in pool.free) == 48 - 10
    # pool still allocates after the rebuild
    assert pool.domain_of(pool.alloc_page()) in (0, 1, 2)


def test_pool_rebalance_spills_overfull_domain(small_cfg):
    pool = _pool(small_cfg, pages=32)   # domain 0 has 16 pages
    ids = []
    while len(ids) < 12:                # fill domain 0 with >8 live pages
        pid = pool.free[0].pop() if pool.free[0] else None
        if pid is None:
            break
        ids.append(pid)
    id_map = pool.rebalance([8, 20, 4])  # domain 0 shrinks below its live set
    assert (id_map[np.asarray(ids)] >= 0).all()
    doms = [pool.domain_of(int(id_map[p])) for p in ids]
    assert sum(1 for d in doms if d == 0) == 8       # kept up to capacity
    assert all(d == 1 for d in doms if d != 0)       # spill to next-fastest


def test_pool_rebalance_raises_when_live_exceeds_capacity(small_cfg):
    pool = _pool(small_cfg, pages=32)
    for _ in range(20):
        pool.alloc_page()
    with pytest.raises(ValueError):
        pool.rebalance([4, 4, 4])


# -- two-stage co-scheduled search (paper §III-B3) ---------------------------

def _drive_cotuner(tuner, stall_a_of_dwp, stall_b_of_dwp, max_periods=60):
    periods = 0
    while not tuner.done and periods < max_periods:
        for _ in range(tuner.cfg.n):
            tuner.record(stall_a_of_dwp(tuner.dwp),
                         stall_b_of_dwp(tuner.dwp))
        periods += 1
    return tuner


def test_cotuner_stage1_freezes_bound_where_a_stabilises():
    canon = interleave.normalize(np.asarray([3.0, 2, 1, 1]))
    t = CoScheduledTuner(canon, workers_b=[0, 1], num_pages=1024)
    # A improves until B's DWP reaches 0.3, then flat
    _drive_cotuner(t, lambda d: max(1.0 - d, 0.7), lambda d: 1.0)
    assert t.stage == 2 or t.done
    assert t.dwp_lower_bound == pytest.approx(0.3, abs=t.cfg.x + 1e-9)


def test_cotuner_stage2_respects_floor_when_optimum_below():
    canon = interleave.normalize(np.asarray([3.0, 2, 1, 1]))
    t = CoScheduledTuner(canon, workers_b=[0, 1], num_pages=1024)
    # bound lands at ~0.4; B's own optimum is at 0.0 — floor must win
    _drive_cotuner(t, lambda d: max(1.0 - d, 0.6), lambda d: 1.0 + d)
    assert t.done
    assert t.dwp_lower_bound >= 0.4 - 1e-9
    assert t.dwp >= t.dwp_lower_bound - 1e-9


def test_cotuner_stage2_climbs_above_bound_when_beneficial():
    canon = interleave.normalize(np.asarray([3.0, 2, 1, 1]))
    t = CoScheduledTuner(canon, workers_b=[0, 1], num_pages=1024)
    # A stabilises immediately (bound ~0.1); B keeps improving with DWP
    _drive_cotuner(t, lambda d: 1.0, lambda d: 2.0 - d)
    assert t.done
    assert t.dwp == pytest.approx(1.0)
    assert t.dwp > t.dwp_lower_bound


# -- domain arbiter -----------------------------------------------------------

SPECS = [
    DomainSpec("hbm_local", 64, 819.0),
    DomainSpec("hbm_peer", 48, 50.0),
    DomainSpec("host", 64, 16.0),
]


def test_arbiter_partitions_capacity_and_homes(small_cfg):
    arb = DomainArbiter(SPECS, page_size=4)
    a = arb.register("A", small_cfg, priority=Priority.HIGH, share=0.5)
    b = arb.register("B", small_cfg, priority=Priority.BEST_EFFORT,
                     share=0.5)
    # disjoint quotas within every domain's budget
    totals = np.asarray([s.total_pages for s in SPECS])
    assert ((a.quotas + b.quotas) <= totals).all()
    assert (arb.free >= 0).all()
    # high-priority claimed the fastest domain; best-effort the next one
    assert a.home == (0,)
    assert b.home == (1,)
    assert b.cotuner is not None and a.cotuner is None
    # both tenants are views over ONE shared fabric pool; each view's
    # quota ledger caps what it can allocate
    assert a.view.pool is b.view.pool
    np.testing.assert_array_equal(a.view.quota, a.quotas)
    assert a.view.capacity() == int(a.quotas.sum())
    assert a.view.free_count() <= a.view.capacity()


def test_arbiter_runs_two_stage_search_from_latency_streams(small_cfg):
    arb = DomainArbiter(SPECS, page_size=4)
    arb.register("A", small_cfg, priority=Priority.HIGH, share=0.4)
    b = arb.register("B", small_cfg, priority=Priority.BEST_EFFORT,
                     share=0.4, dwp_config=DWPConfig(n=2, c=0))
    for _ in range(200):
        if b.cotuner.done:
            break
        d = b.dwp
        arb.observe("A", max(1.0 - 2 * d, 0.6))     # improves until d=0.2
        arb.observe("B", (d - 0.1) ** 2 + 1.0)      # optimum below the bound
    assert b.cotuner.done
    assert b.cotuner.dwp_lower_bound >= 0.2 - 1e-9
    assert b.dwp >= b.cotuner.dwp_lower_bound - 1e-9


def test_arbiter_observe_rehomes_view_sequences(small_cfg):
    """Cycle moves from the co-scheduled search re-home live pages through
    the view's assignment-change subscription — no attach_engine."""
    arb = DomainArbiter(SPECS, page_size=4)
    arb.register("A", small_cfg, priority=Priority.HIGH, share=0.4)
    b = arb.register("B", small_cfg, priority=Priority.BEST_EFFORT,
                     share=0.4, dwp_config=DWPConfig(n=2, c=0))
    seq = type("S", (), {})()
    seq.pages = []
    for _ in range(6):
        b.view.append_page(seq.pages)
    b.view.on_assignment_change(
        lambda: seq.__setattr__("pages", b.view.migrate(seq.pages)))
    moved_any = False
    for _ in range(40):
        arb.observe("A", 1.0 - 0.5 * b.dwp)         # keep stage 1 climbing
        moved_any |= arb.observe("B", 1.0)
        if b.dwp >= 0.5:
            break
    assert moved_any
    # pages were re-homed (valid ids, ledgers consistent) as B's DWP rose
    assert all(p < b.view.pool.total_pages for p in seq.pages)
    arb.fabric.check_invariants()
    b.view.release(seq.pages)
    arb.fabric.check_invariants()


def test_arbiter_pins_hottest_preambles(small_cfg):
    """The arbiter's pin selection ranks cross-tenant chains by
    refcount × heat and pins the winners into the persistence tier;
    re-selection refreshes the LRU stamp instead of duplicating pins."""
    from repro.obs.observatory import Observatory
    from repro.placement.persist import PersistentTier

    arb = DomainArbiter(SPECS, page_size=4)
    a = arb.register("A", small_cfg, priority=Priority.HIGH, share=0.4)
    b = arb.register("B", small_cfg, priority=Priority.BEST_EFFORT,
                     share=0.4)
    tier = PersistentTier(capacity_pages=64)
    arb.fabric.attach_persist(tier)
    obs = Observatory(arb.fabric, tracer=False, drift=False)

    def chain(toks, val):
        pages = []
        for i in range(len(toks) // 4):
            a.view.append_page(pages)
        a.view.register_prefix(list(toks), pages, len(toks))
        return pages

    cold = chain(list(range(100, 108)), 1)     # cross-tenant shared, cool
    hot = chain(list(range(200, 208)), 2)      # cross-tenant shared, hot
    private = chain(list(range(300, 308)), 3)  # only tenant A: ref 1
    shared_b = []
    for toks in (list(range(100, 108)), list(range(200, 208))):
        got = []
        assert b.view.probe_prefix(toks, got) == 8    # B shares: ref -> 2
        shared_b.append(got)
    for _ in range(5):
        obs.heat.touch(hot)

    keys = arb.pin_hot_preambles(top_k=1, min_ref=2)
    assert len(keys) == 1 and keys[0] in tier._pins
    assert tier.pinned_pages() == set(hot)     # heat broke the ref tie
    assert not (set(private) & tier.pinned_pages())
    stamp0 = tier._pins[keys[0]]["stamp"]
    assert arb.pin_hot_preambles(top_k=1, min_ref=2) == keys
    assert tier._pins[keys[0]]["stamp"] > stamp0   # touched, not re-pinned

    # with room for two, the cool shared chain joins; the private never does
    keys2 = arb.pin_hot_preambles(top_k=3, min_ref=2)
    assert tier.pinned_pages() == set(hot) | set(cold)
    assert len(keys2) == 2
    arb.fabric.check_invariants()


def test_arbiter_unregister_redistributes_quota(small_cfg):
    """Tenant leave is pure ledger arithmetic on the shared fabric: the
    survivor's quota grows in place — no pool rebuild, no id remapping,
    live pages untouched."""
    arb = DomainArbiter(SPECS, page_size=4)
    a = arb.register("A", small_cfg, priority=Priority.HIGH, share=0.5)
    b = arb.register("B", small_cfg, priority=Priority.BEST_EFFORT,
                     share=0.5)
    seq = type("S", (), {})()
    seq.pages = []
    for _ in range(5):
        a.view.append_page(seq.pages)
    pages_before = list(seq.pages)
    quota_before = a.quotas.copy()
    b_quota = b.quotas.copy()
    grants = arb.unregister("B")
    np.testing.assert_array_equal(a.quotas, quota_before + grants["A"])
    np.testing.assert_array_equal(grants["A"], b_quota)   # sole survivor
    np.testing.assert_array_equal(a.view.quota, a.quotas)
    assert seq.pages == pages_before                # live pages untouched
    assert "B" not in arb.tenants
    assert "B" not in arb.fabric.views
    # all freed capacity went to the sole survivor...
    assert (arb.free == 0).all()
    # ...and B's home domain is claimable again
    assert 1 not in arb._claimed_homes
    arb.fabric.check_invariants()


def test_arbiter_interference_tracks_foreign_residency(small_cfg):
    arb = DomainArbiter(SPECS, page_size=4)
    arb.register("A", small_cfg, priority=Priority.HIGH, share=0.4)
    b = arb.register("B", small_cfg, priority=Priority.BEST_EFFORT,
                     share=0.4)
    base = arb.interference("A")
    # push B pages onto A's home domain (domain 0): allocate until the
    # view ledger shows residency there
    pages = []
    while int(b.view.used_pages()[0]) < 4:
        b.view.append_page(pages)
    assert arb.interference("A") > base
    b.view.release(pages)


# -- checkpoint staging through the registry ---------------------------------

def test_ckpt_plan_staging_spreads_bytes_by_bandwidth():
    from repro.checkpoint.ckpt import StagingTier, plan_staging
    tiers = [StagingTier("host", 16.0, 1 << 34),
             StagingTier("peer", 4.0, 1 << 34)]
    plan = plan_staging([10 << 20, 30 << 20], tiers)
    total = sum(plan["tiers"].values())
    assert total == 40 << 20
    # canonical split ∝ bandwidth: host gets ~4x the peer bytes
    assert plan["tiers"]["host"] > 3 * plan["tiers"]["peer"]
    assert plan["drain_time_s"] > 0


def test_ckpt_manager_records_staging_plan(tmp_path):
    import json

    from repro.checkpoint.ckpt import CheckpointManager, StagingTier
    mgr = CheckpointManager(tmp_path, staging_tiers=[
        StagingTier("host", 16.0, 1 << 34),
        StagingTier("nvme", 2.0, 1 << 34)])
    tree = {"w": np.ones((64, 64), np.float32)}
    mgr.save(3, tree)
    manifest = json.loads(
        (tmp_path / "step_0000000003" / "manifest.json").read_text())
    staging = manifest["staging"]
    assert set(staging["tiers"]) == {"host", "nvme"}
    assert staging["policy"] == "bwap_canonical"
    step, restored = mgr.restore(like=tree)
    assert step == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_ckpt_staging_overflow_does_not_abort_save(tmp_path):
    import json

    from repro.checkpoint.ckpt import CheckpointManager, StagingTier
    mgr = CheckpointManager(tmp_path, staging_tiers=[
        StagingTier("tiny", 16.0, 2 << 20)])       # 2 MiB < leaf size
    tree = {"w": np.ones((1024, 1024), np.float32)}
    mgr.save(1, tree)                              # must still publish
    manifest = json.loads(
        (tmp_path / "step_0000000001" / "manifest.json").read_text())
    assert "error" in manifest["staging"]
    step, restored = mgr.restore(like=tree)
    assert step == 1
    np.testing.assert_array_equal(restored["w"], tree["w"])


# -- engine surfaces telemetry ------------------------------------------------

def test_engine_step_reports_telemetry(small_cfg):
    import jax

    from repro.models.lm import LM
    from repro.serve.engine import ServeEngine
    cfg = dataclasses.replace(small_cfg, num_layers=2)
    params = LM(cfg).init(jax.random.PRNGKey(0))
    pool = _pool(cfg, pages=64)
    eng = ServeEngine(cfg, params, pool, max_batch=2, max_new=3)
    eng.submit([3, 5, 7, 11])
    info = eng.step()
    tel = info["telemetry"]
    assert sum(d["allocs"] for d in tel["domains"].values()) > 0
    assert tel["latency_last_s"] > 0
