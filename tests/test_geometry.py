"""Geometry-polymorphic page fabric + capacity market (ISSUE 9 /
DESIGN.md §12).

Covers the `PageGeometry` protocol (paged K/V bit-identity with the
historical layout, MLA latent asymmetry, 1-page SSM state, read-only
encoder K/V), the pool/pagetable behavior it induces (asymmetric arrays,
fork-as-copy vs fork-as-refcount, prefix trie gated off for
non-shareable groups), the deprecation shims for the old serve-layer
import paths, the `PageFabricZoo` byte ledger + capacity market
(annex / escrow / repay / leak-free unregister), and a hypothesis
property test interleaving alloc / fork / migrate / release / market
ticks across a transformer + MLA + SSM trio.
"""

import dataclasses
import importlib
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

import jax.numpy as jnp

from repro.configs import registry
from repro.placement.geometry import (PageGeometry, encoder_kv_geometry,
                                      geometry_for, mla_latent_geometry,
                                      paged_kv_geometry, ssm_state_geometry)
from repro.placement.pool import BwapPagePool, MemoryDomain
from repro.placement.zoo import ByteDomain, PageFabricZoo


def _cfg(name, **over):
    cfg = registry.get_smoke_config(name)
    return dataclasses.replace(cfg, **over) if over else cfg


CHAT = _cfg("qwen2-0.5b", num_layers=1, compute_dtype="float32")
MLA = _cfg("deepseek-v3-671b")
SSM = _cfg("xlstm-125m")
ASR = _cfg("whisper-tiny")


def _domains(fast=32, slow=24):
    return [MemoryDomain("hbm_local", fast, 819.0, True),
            MemoryDomain("host", slow, 16.0, False)]


def _arena():
    return [ByteDomain("hbm_local", 64 * 1024, 819.0, True),
            ByteDomain("host", 128 * 1024, 8.0)]


# ---------------------------------------------------------------------------
# the geometry protocol
# ---------------------------------------------------------------------------

def test_paged_geometry_matches_historical_layout():
    """The default geometry reproduces the old hardcoded pool layout
    bit-for-bit: same page_bytes formula, same array shapes."""
    ps = 4
    g = geometry_for(CHAT, ps)
    assert g.kind == "paged_kv" and g.shareable and g.grows
    itemsize = jnp.dtype(CHAT.compute_dtype).itemsize
    old = 2 * ps * CHAT.num_kv_heads * CHAT.head_dim_ \
        * itemsize * CHAT.num_layers
    assert g.page_bytes == old
    k, v = g.array_shapes(10)
    assert k == v == (CHAT.num_layers, 10, ps, CHAT.num_kv_heads,
                      CHAT.head_dim_)
    assert g.pages_for_tokens(0) == 0
    assert g.pages_for_tokens(1) == 1
    assert g.pages_for_tokens(9) == 3


def test_mla_geometry_is_asymmetric_and_compressed():
    g = geometry_for(MLA, 4)
    assert g.kind == "mla_latent" and g.shareable and g.grows
    assert g.k_block == (4, 1, MLA.mla.qk_rope_head_dim)
    assert g.v_block == (4, 1, MLA.mla.kv_lora_rank)
    assert g.k_block != g.v_block, "latent cache must be asymmetric"
    # the whole point: far below the materialized-heads footprint
    assert g.page_bytes < paged_kv_geometry(MLA, 4).page_bytes
    assert g.page_bytes == (4 * (MLA.mla.qk_rope_head_dim
                                 + MLA.mla.kv_lora_rank)
                            * jnp.dtype(MLA.compute_dtype).itemsize
                            * MLA.num_layers)


def test_ssm_geometry_is_one_fixed_nonshareable_page():
    g = geometry_for(SSM, 4)                # page_size arg ignored: state
    assert g.kind == "ssm_state"
    assert g.page_size == 1 and g.fixed_pages == 1 and not g.grows
    assert not g.shareable, "in-place-mutated state must not CoW-alias"
    for tokens in (0, 1, 7, 10 ** 6):       # never grows
        assert g.pages_for_tokens(tokens) == 1
    assert math.prod(g.k_block) != math.prod(g.v_block)


def test_encoder_geometry_is_fixed_and_shareable():
    g = encoder_kv_geometry(ASR, 4)
    assert g.kind == "encoder_kv" and g.shareable and not g.grows
    assert g.fixed_pages == -(-ASR.enc_frames // 4)
    assert g.num_layers == ASR.enc_layers
    # never the default: whisper's decode-path cache stays paged K/V
    assert geometry_for(ASR, 4).kind == "paged_kv"


# ---------------------------------------------------------------------------
# pool + pagetable under a geometry (satellite: page_bytes from geometry)
# ---------------------------------------------------------------------------

def test_pool_defaults_are_bit_identical():
    pool = BwapPagePool(CHAT, _domains(), page_size=4)
    g = pool.geometry
    assert g.kind == "paged_kv"
    assert pool.page_bytes == g.page_bytes
    assert pool.k_pool.shape == pool.v_pool.shape \
        == g.array_shapes(pool.total_pages)[0]
    pid = pool.alloc_page()
    assert pool.bytes_per_domain([pid])[0] == g.page_bytes


def test_pool_materializes_asymmetric_mla_arrays():
    pool = BwapPagePool(MLA, _domains(), page_size=4)
    assert pool.geometry.kind == "mla_latent"
    assert pool.k_pool.shape != pool.v_pool.shape
    assert pool.k_pool.shape[-1] == MLA.mla.qk_rope_head_dim
    assert pool.v_pool.shape[-1] == MLA.mla.kv_lora_rank
    assert pool.page_bytes == pool.geometry.page_bytes


def test_ssm_pool_follows_geometry_page_size():
    pool = BwapPagePool(SSM, _domains(), page_size=4)
    assert pool.geometry.kind == "ssm_state"
    assert pool.page_size == 1, "pool token granularity follows geometry"


def test_prefix_trie_gated_off_for_nonshareable_geometry():
    from repro.placement.fabric import MemoryFabric
    fab = MemoryFabric(SSM, _domains(), page_size=1, seed=0)
    view = fab.view("s", quota=(8, 6), home=(0,))
    pages = []
    view.append_page(pages)
    view.register_prefix([1], pages, 1)     # must be a silent no-op
    probe = []
    assert view.probe_prefix([1], probe) == 0 and probe == []
    view.release(pages)
    fab.check_invariants()


def test_fork_semantics_copy_vs_refcount():
    from repro.placement.fabric import MemoryFabric
    # SSM: fork copies state into fresh pages
    fab = MemoryFabric(SSM, _domains(), page_size=1, seed=0)
    v = fab.view("s", quota=(8, 6), home=(0,))
    pages = []
    v.append_page(pages)
    v.k_pool = v.k_pool.at[:, pages[0]].set(3.0)
    clone = v.fork_sequence(pages)
    assert clone and set(clone).isdisjoint(pages), "SSM fork must copy"
    np.testing.assert_array_equal(np.asarray(v.k_pool)[:, clone[0]],
                                  np.asarray(v.k_pool)[:, pages[0]])
    assert all(fab.table.ref[p] == 1 for p in pages + clone)
    # shareable: fork bumps refcounts, no new pages
    fab2 = MemoryFabric(CHAT, _domains(), page_size=4, seed=0)
    v2 = fab2.view("c", quota=(8, 6), home=(0,))
    pages2 = []
    v2.grow(pages2, 2)
    free_before = v2.free_count()
    clone2 = v2.fork_sequence(pages2)
    assert clone2 == pages2 and v2.free_count() == free_before
    assert all(fab2.table.ref[p] == 2 for p in pages2)
    v2.release(clone2)
    v2.release(pages2)
    for f in (fab, fab2):
        f.check_invariants()


# ---------------------------------------------------------------------------
# deprecation shims (satellite: old serve-layer import paths keep working)
# ---------------------------------------------------------------------------

def test_serve_kvcache_shim_warns_and_reexports():
    import repro.serve.kvcache as shim
    with pytest.warns(DeprecationWarning, match="repro.serve.kvcache"):
        shim = importlib.reload(shim)
    from repro.placement import pool
    assert shim.BwapPagePool is pool.BwapPagePool
    assert shim.MemoryDomain is pool.MemoryDomain
    assert shim.default_domains is pool.default_domains


def test_serve_pagetable_shim_warns_and_reexports():
    import repro.serve.pagetable as shim
    with pytest.warns(DeprecationWarning, match="repro.serve.pagetable"):
        shim = importlib.reload(shim)
    from repro.placement import pagetable
    assert shim.PageTable is pagetable.PageTable
    assert shim.ROOT is pagetable.ROOT


# ---------------------------------------------------------------------------
# the zoo: byte arena + capacity market
# ---------------------------------------------------------------------------

def _zoo():
    zoo = PageFabricZoo(_arena(), seed=0)
    zoo.register("chat", CHAT, share=0.25, page_size=4)
    zoo.register("mla", MLA, share=0.25, page_size=4)
    zoo.register("ssm", SSM, share=0.3)
    return zoo


def test_zoo_three_geometries_one_arena():
    zoo = _zoo()
    kinds = {g.geometry.kind for g in zoo.groups.values()}
    assert kinds == {"paged_kv", "mla_latent", "ssm_state"}
    # funding is byte-denominated: floor(share * capacity / page_bytes)
    for g in zoo.groups.values():
        assert (g.funded_bytes()
                <= np.asarray([0.31 * d.capacity_bytes
                               for d in zoo.domains])).all()
    zoo.check_invariants()


def test_zoo_market_annex_and_repay():
    zoo = _zoo()
    chat = zoo.groups["chat"]
    start = {n: g.view.quota.copy() for n, g in zoo.groups.items()}
    # a chat burst: demand far beyond its funding, everyone else idle
    zoo.observe_demand("chat", 80 * chat.page_bytes)
    assert zoo.page_value("chat") > zoo.page_value("ssm") == 0.0
    flows = zoo.market_tick()
    assert flows["granted_bytes"] > 0
    assert {ln.lender for ln in zoo.leases if ln.granted_bytes} \
        <= {"mla", "ssm"}
    assert (chat.view.quota > start["chat"]).any()
    zoo.check_invariants()
    # burst over: demand drops, the next tick unwinds every lease
    zoo.observe_demand("chat", 0)
    zoo.market_tick()
    assert zoo.outstanding_bytes() == 0
    for n, q in start.items():
        np.testing.assert_array_equal(zoo.groups[n].view.quota, q)
    zoo.check_invariants()


def test_zoo_escrow_balances_mismatched_page_sizes():
    """SSM pages (16+ KiB) never divide chat pages (1 KiB): a trade must
    escrow the remainder bytes in the lease, and the ledger must balance
    mid-lease, not just after repayment."""
    zoo = _zoo()
    chat = zoo.groups["chat"]
    zoo.observe_demand("chat", 10 ** 9)     # starve: annex everything idle
    zoo.market_tick()
    zoo.check_invariants()                  # balances WITH escrow held
    ssm_leases = [ln for ln in zoo.leases
                  if ln.lender == "ssm" and ln.granted_bytes]
    assert ssm_leases, "ssm funding never traded"
    ln = ssm_leases[0]
    lent = int(ln.lender_pages.sum()) * zoo.groups["ssm"].page_bytes
    funded = int(ln.borrower_pages.sum()) * chat.page_bytes
    assert lent == funded + int(ln.escrow_bytes.sum())


def test_zoo_unregister_is_leak_free():
    zoo = _zoo()
    cap = zoo.capacity_bytes.copy()
    for name in list(zoo.groups):
        zoo.unregister(name)
    np.testing.assert_array_equal(zoo.free_bytes(), cap)


def test_zoo_rejects_oversubscription():
    zoo = PageFabricZoo(_arena(), seed=0)
    zoo.register("a", CHAT, share=0.7, page_size=4)
    with pytest.raises(AssertionError, match="oversubscribe"):
        zoo.register("b", CHAT, share=0.5, page_size=4)


# ---------------------------------------------------------------------------
# property test: the trio under random interleavings
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 2),
                          st.integers(0, 10 ** 6)),
                min_size=1, max_size=40),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_zoo_invariants_under_random_interleavings(ops, seed):
    """Random interleavings of alloc / fork / migrate / release / market
    ticks across the transformer + MLA + SSM trio hold the zoo byte
    ledger (funded + escrow + free == capacity per domain) and every
    member fabric's page invariants after every operation — and a full
    drain + unregister leaks nothing."""
    zoo = _zoo()
    names = list(zoo.groups)
    rng = np.random.default_rng(seed)
    seqs = {n: [] for n in names}

    for op, gi, arg in ops:
        name = names[gi]
        g = zoo.groups[name]
        v, mine = g.view, seqs[name]
        if op == 0:                        # alloc
            n = 1 if not g.geometry.grows else int(rng.integers(1, 4))
            if v.free_count() < n:
                continue
            pages = []
            if g.geometry.grows:
                v.grow(pages, n)
            else:
                for _ in range(g.geometry.fixed_pages):
                    v.append_page(pages)
            mine.append(pages)
        elif op == 1 and mine:             # fork: copy or refcount
            pages = mine[arg % len(mine)]
            if not g.geometry.shareable \
                    and v.free_count() < len(pages):
                continue
            mine.append(v.fork_sequence(pages))
        elif op == 2 and mine:             # migrate live pages
            i = arg % len(mine)
            mine[i] = v.migrate(mine[i])
        elif op == 3 and mine:             # release
            v.release(mine.pop(arg % len(mine)))
        elif op == 4:                      # market tick under this demand
            for other in names:
                zoo.observe_demand(other, 0)
            zoo.observe_demand(name, arg * g.page_bytes)
            zoo.market_tick()
        zoo.check_invariants()

    # drain: everything releases, demand clears, leases unwind, and
    # unregistering the whole zoo returns every byte to the arena
    cap = zoo.capacity_bytes.copy()
    for name in names:
        for pages in seqs[name]:
            zoo.groups[name].view.release(pages)
        zoo.observe_demand(name, 0)
    zoo.market_tick()
    assert zoo.outstanding_bytes() == 0, "idle leases must fully repay"
    zoo.check_invariants()
    for name in names:
        zoo.unregister(name)
    np.testing.assert_array_equal(zoo.free_bytes(), cap)
