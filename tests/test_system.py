"""End-to-end behaviour tests for the BWAP system.

The full placement pipeline: profile -> canonical weights -> Alg. 1 page
table -> online DWP tuning -> migration, exercised through the public API
exactly the way the launchers use it, plus the dry-run driver on a real
cell (subprocess keeps the host-device-count flag scoped).
"""

import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_full_bwap_pipeline_beats_baselines():
    """install-time sweep -> runtime tuner -> final placement outperforms
    first-touch and uniform-workers on the asymmetric machine."""
    from repro.core import interleave
    from repro.core.canonical import CanonicalTuner
    from repro.core.dwp import DWPConfig, DWPTuner
    from repro.core.simulator import PAPER_WORKLOADS, NumaSimulator
    from repro.core.topology import machine_a

    mach = machine_a()
    sim = NumaSimulator(mach)
    tuner = CanonicalTuner(mach)
    app = PAPER_WORKLOADS["SC"]
    workers = [0, 1]
    canon = tuner.weights_for(workers).weights

    dwp = DWPTuner(canon, workers, num_pages=4096,
                   config=DWPConfig(n=6, c=1, rel_tolerance=0.02))
    while not dwp.done:
        w = interleave.dwp_weights(canon, workers, dwp.dwp)
        stall = sim.run(app, workers, "weighted", w, noise=0.01).stall_rate
        dwp.record(stall)

    w = interleave.dwp_weights(canon, workers, dwp.dwp)
    t_bwap = sim.run(app, workers, "weighted", w).time
    assert t_bwap <= sim.run(app, workers, "uniform_workers").time
    assert t_bwap <= sim.run(app, workers, "first_touch").time
    # placement integrity: page table matches tuned weights
    frac = interleave.page_fractions(dwp.assignment, mach.num_nodes)
    np.testing.assert_allclose(frac, w, atol=0.01)


def test_canonical_install_sweep_covers_plausible_sets(tmp_path):
    from repro.core.canonical import CanonicalTuner
    from repro.core.topology import machine_a

    tuner = CanonicalTuner(machine_a())
    n = tuner.install(tmp_path / "w.json", max_size=2)
    assert n >= 3      # several distinct 1- and 2-node worker sets
    loaded = CanonicalTuner.load(tmp_path / "w.json")
    for ws, w in loaded.items():
        assert abs(w.sum() - 1.0) < 1e-9
        assert (w > 0).all()


def test_dryrun_driver_small_cell():
    """The dry-run driver end-to-end on one real cell (subprocess for the
    512-device flag). Uses the smallest arch/shape for speed."""
    script = textwrap.dedent("""
        from repro.launch.dryrun import run_cell, roofline_record
        rec = run_cell("xlstm-125m", "decode_32k", multi_pod=False,
                       verbose=False)
        assert rec["status"] == "OK", rec.get("error")
        rl = roofline_record(rec)
        assert rl and rl["t_memory"] > 0
        assert rec["memory"]["total_bytes_per_device"] < 16 * 2**30
        print("DRYRUN_OK", rl["bottleneck"])
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=str(ROOT), timeout=560)
    assert "DRYRUN_OK" in r.stdout, (r.stdout[-500:], r.stderr[-1500:])
