"""Per-architecture smoke tests: reduced config, one forward/train step and
one decode step on CPU; asserts output shapes and no NaNs (assignment (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry


def _batch_for(cfg, b=2, s=16, key=0):
    rng = np.random.default_rng(key)
    batch = {}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_frames, cfg.d_model)),
            jnp.dtype(cfg.compute_dtype))
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        return batch
    if cfg.frontend == "vision_stub":
        p = min(cfg.vision_patches, s // 2)
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, p, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - p)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_forward_loss(arch):
    cfg = registry.get_smoke_config(arch)
    model = registry.make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (arch, metrics)
    assert float(loss) > 0


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_grads_finite(arch):
    cfg = registry.get_smoke_config(arch)
    model = registry.make_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    flat, _ = jax.tree.flatten(grads)
    assert all(jnp.isfinite(g).all() for g in flat), arch
    # at least some gradient signal somewhere
    assert any(float(jnp.abs(g).max()) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = registry.get_smoke_config(arch)
    model = registry.make_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    b, cap = 2, 32
    if cfg.enc_dec:
        cache = model.init_cache(params, b, cap, cfg.enc_frames)
    else:
        cache = model.init_cache(b, cap)
    tokens = jnp.ones((b, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tokens, jnp.int32(0))
    logits2, cache = step(params, cache, tokens, jnp.int32(1))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all() and jnp.isfinite(logits2).all(), arch
    # cache actually evolves
    assert not jax.tree.all(jax.tree.map(
        lambda a, b_: jnp.array_equal(a, b_), cache,
        (model.init_cache(params, b, cap, cfg.enc_frames)
         if cfg.enc_dec else model.init_cache(b, cap))))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_smoke_prefill(arch):
    cfg = registry.get_smoke_config(arch)
    model = registry.make_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = _batch_for(cfg)
    logits = jax.jit(model.prefill)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert jnp.isfinite(logits).all(), arch


def test_decode_matches_prefill_qwen2():
    """Decode-step logits must match full-forward logits position by
    position (cache correctness, non-windowed dense arch)."""
    cfg = registry.get_smoke_config("qwen2-0.5b")
    model = registry.make_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    b, s = 2, 8
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (b, s)),
        jnp.int32)
    # full forward
    x = model.embed(params, {"tokens": tokens})
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _, _ = model.hidden(params, x, pos)
    full_logits = model.logits(params, h)
    # token-by-token decode
    cache = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, cache = jax.jit(model.decode_step)(params, cache,
                                               tokens[:, t:t + 1],
                                               jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                               np.asarray(dec_logits, np.float32),
                               rtol=0.05, atol=0.05)


def test_decode_matches_prefill_mla():
    """Absorbed-matrix MLA decode must match the materialized training-path
    attention (deepseek smoke config, dense-layer + MoE layers).

    fp32 compute + no-drop capacity: isolates cache/absorption correctness
    from bf16 rounding and MoE capacity drops (verified separately)."""
    import dataclasses
    cfg = registry.get_smoke_config("deepseek-v3-671b")
    cfg = dataclasses.replace(
        cfg, compute_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = registry.make_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    b, s = 2, 8
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (b, s)),
        jnp.int32)
    x = model.embed(params, {"tokens": tokens})
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, _, _ = model.hidden(params, x, pos)
    full_logits = model.logits(params, h)
    cache = model.init_cache(b, s)
    outs = []
    for t in range(s):
        lg, cache = jax.jit(model.decode_step)(params, cache,
                                               tokens[:, t:t + 1],
                                               jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full_logits, np.float32),
                               np.asarray(dec_logits, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_mask_effective():
    """A token outside the window must not influence the current logits.

    Single layer: with stacked window layers the receptive field legally
    grows by (window-1) per layer, so only the 1-layer case is a strict
    no-influence guarantee."""
    cfg = registry.get_smoke_config("gemma3-27b")
    cfg = cfg.__class__(**{**cfg.__dict__, "global_every": 0,
                           "sliding_window": 4, "num_layers": 1,
                           "compute_dtype": "float32"})
    model = registry.make_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    rng = np.random.default_rng(2)
    t1 = rng.integers(1, cfg.vocab_size, (1, 12))
    t2 = t1.copy()
    t2[0, 0] = (t2[0, 0] + 7) % cfg.vocab_size   # mutate a far-past token
    def last_logits(tok):
        x = model.embed(params, {"tokens": jnp.asarray(tok, jnp.int32)})
        pos = jnp.broadcast_to(jnp.arange(12, dtype=jnp.int32), (1, 12))
        h, _, _ = model.hidden(params, x, pos)
        return model.logits(params, h)[:, -1]
    np.testing.assert_allclose(np.asarray(last_logits(t1), np.float32),
                               np.asarray(last_logits(t2), np.float32),
                               rtol=1e-4, atol=1e-4)


def test_param_counts_match_assignment():
    """Total parameter counts are in the right ballpark for the headline
    sizes (sanity for roofline MODEL_FLOPS)."""
    expect = {
        "deepseek-v3-671b": (600e9, 760e9),
        "gemma3-27b": (23e9, 31e9),
        "internlm2-20b": (17e9, 23e9),
        "qwen2-0.5b": (0.4e9, 0.65e9),
        "gemma-2b": (2.0e9, 3.2e9),
        "qwen2-vl-7b": (6.5e9, 8.5e9),
        "hymba-1.5b": (1.1e9, 2.0e9),
        "xlstm-125m": (0.10e9, 0.20e9),
        "granite-moe-3b-a800m": (2.5e9, 3.9e9),
        "whisper-tiny": (0.025e9, 0.06e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get_config(arch).param_counts()["total"]
        assert lo <= n <= hi, (arch, f"{n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]")
