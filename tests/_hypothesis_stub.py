"""Fallback shims for environments without hypothesis (optional dep).

Property tests decorated with the stub ``given`` skip individually at run
time, so the plain tests in the same module still execute — a module-level
``pytest.importorskip`` would take them all down with it.
"""

import pytest


class _Strategy:
    """Placeholder for any strategy object; only built at decoration time."""

    def __repr__(self):
        return "<hypothesis-missing>"

    def filter(self, *a, **k):
        return self

    def map(self, *a, **k):
        return self


class _St:
    """Stands in for ``hypothesis.strategies``."""

    @staticmethod
    def composite(fn):
        return lambda *a, **k: _Strategy()

    def __getattr__(self, name):
        return lambda *a, **k: _Strategy()


st = _St()


def given(*_a, **_k):
    def deco(fn):
        # zero-arg stand-in: pytest must not try to resolve the strategy
        # parameters as fixtures
        def skipper():
            pytest.skip("hypothesis not installed")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_a, **_k):
    return lambda fn: fn
