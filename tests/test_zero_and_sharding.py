"""Weighted-ZeRO placement, sharding rules, and the shard_map all-gather."""

import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

from repro.sharding import zero


def _tiers(caps=(100, 1000, 1000), bws=(50.0, 12.5, 16.0)):
    return [zero.TierSpec(f"t{i}", b, c)
            for i, (b, c) in enumerate(zip(bws, caps))]


def test_tier_split_proportional_when_unconstrained():
    tiers = _tiers(caps=(10_000, 10_000, 10_000))
    a = zero.tier_split(1000, tiers)
    frac = np.bincount(a, minlength=3) / 1000
    bw = np.asarray([50.0, 12.5, 16.0])
    np.testing.assert_allclose(frac, bw / bw.sum(), atol=0.02)


def test_tier_split_respects_capacity():
    tiers = _tiers(caps=(100, 10_000, 10_000))
    a = zero.tier_split(1000, tiers)
    counts = np.bincount(a, minlength=3)
    assert counts[0] <= 100
    assert counts.sum() == 1000


def test_bwap_tier_split_dominates_baselines():
    """Eq.-1 cost: BWAP split is never slower than uniform or fastest-first
    across a sweep of capacity pressures."""
    for cap0 in (100, 300, 500, 800, 1000):
        tiers = _tiers(caps=(cap0, 2000, 2000))
        t_b = zero.stream_update_time(zero.tier_split(1000, tiers), tiers,
                                      1 << 20)
        t_u = zero.stream_update_time(zero.uniform_split(1000, tiers),
                                      tiers, 1 << 20)
        t_h = zero.stream_update_time(zero.hbm_first_split(1000, tiers),
                                      tiers, 1 << 20)
        assert t_b <= t_u + 1e-9, cap0
        assert t_b <= t_h + 1e-9, cap0


@given(st.lists(st.floats(min_value=1.0, max_value=900.0),
                min_size=2, max_size=5),
       st.integers(min_value=64, max_value=512))
@settings(max_examples=25, deadline=None)
def test_weighted_partition_fractions(bws, pages):
    a = zero.weighted_page_partition(pages, np.asarray(bws))
    frac = np.bincount(a, minlength=len(bws)) / pages
    w = np.asarray(bws) / np.sum(bws)
    np.testing.assert_allclose(frac, w, atol=len(bws) * 1.5 / pages + 1e-9)


def test_weighted_allgather_multidevice():
    """shard_map weighted all-gather on 8 host devices (subprocess keeps the
    device-count flag scoped)."""
    if not hasattr(jax, "shard_map") or \
            not hasattr(jax.sharding, "AxisType"):
        pytest.skip("installed jax lacks jax.shard_map/AxisType")
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding import zero

        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        pages, width = 32, 16
        owner = zero.weighted_page_partition(
            pages, np.asarray([4.0, 2, 1, 1, 1, 1, 1, 1]))
        full = jnp.arange(pages * width, dtype=jnp.float32).reshape(
            pages, width)
        # each rank only holds its pages
        def local_view(rank):
            mask = (owner == rank)[:, None]
            return jnp.where(mask, full, 0.0)
        # simulate: every rank starts from its own masked copy; psum-based
        # gather must reconstruct the full table
        out = zero.weighted_allgather(local_view(0) * 0 + sum(
            np.asarray(local_view(r)) * 0 for r in range(8)) + local_view(0),
            owner, mesh)
        # rank-0 view only has rank-0 pages; after gather those pages match
        got = np.asarray(out)
        mask0 = (owner == 0)
        assert np.allclose(got[mask0], np.asarray(full)[mask0])
        print("ALLGATHER_OK")
    """)
    import os
    import pathlib
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=str(pathlib.Path(__file__).resolve().parents[1]),
                       timeout=300)
    assert "ALLGATHER_OK" in r.stdout, r.stderr[-1500:]


def test_param_sharding_rules_head_alignment():
    """Attention TP only when heads divide the model axis (the 14-GiB
    all-reduce regression test, in rule form)."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import registry
    from repro.sharding import specs as sh

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    mesh = FakeMesh()
    qwen = registry.get_config("qwen2-0.5b")        # 14 heads: replicate
    intern = registry.get_config("internlm2-20b")   # 48 heads: shard

    s_q = sh.param_spec_for(qwen, mesh, (), (896, 896))
    # ^ generic path; use named path for wq
    import jax.tree_util as jtu
    path = (jtu.DictKey("attn"), jtu.DictKey("wq"))
    assert sh.param_spec_for(qwen, mesh, path, (896, 896)) == P(None, None)
    assert sh.param_spec_for(intern, mesh, path, (6144, 6144)) == \
        P(None, "model")
    # MLP stays TP for both
    path_mlp = (jtu.DictKey("mlp"), jtu.DictKey("w_up"))
    assert sh.param_spec_for(qwen, mesh, path_mlp, (896, 4864)) == \
        P(None, "model")
