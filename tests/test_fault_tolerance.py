"""Fault tolerance: checkpoint/restart, failure recovery, elastic resharding,
straggler mitigation, gradient compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import BwapDataRouter, PrefetchLoader, \
    ShardedTokenDataset
from repro.models.lm import LM
from repro.train import optimizer as opt_mod
from repro.train.loop import LoopConfig, SimulatedFailure, Trainer


def _tiny():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, num_layers=2, d_ff=64)
    return cfg, LM(cfg)


def _batch_fn(cfg, bs=4, s=16):
    def f(step):
        rng = np.random.default_rng(step)
        return {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (bs, s)), jnp.int32)}
    return f


def test_checkpoint_roundtrip_and_hash(tmp_path):
    cm = CheckpointManager(tmp_path)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    cm.save(7, tree, metadata={"x": 1})
    step, out = cm.restore(like=tree)
    assert step == 7
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
    # corrupt a tensor file -> integrity error
    f = next((tmp_path / "step_0000000007").glob("arr_*.npy"))
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        cm.restore(like=tree)


def test_checkpoint_gc_keeps_last(tmp_path):
    cm = CheckpointManager(tmp_path, keep_last=2)
    t = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        cm.save(s, t)
    names = sorted(p.name for p in tmp_path.glob("step_*"))
    assert names == ["step_0000000003", "step_0000000004"]


def test_crash_restart_resumes_identically(tmp_path):
    """A crash mid-run restarts from the checkpoint and converges to the
    same state as an uninterrupted run (deterministic data + updates)."""
    cfg, model = _tiny()
    ocfg = opt_mod.OptConfig(lr=1e-3, warmup_steps=2, total_steps=12)

    def trainer(d):
        return Trainer(model, ocfg, LoopConfig(total_steps=12, ckpt_every=4,
                                               log_every=100),
                       str(d), _batch_fn(cfg))

    # uninterrupted
    t1 = trainer(tmp_path / "a")
    _, p_ref, _, m_ref = t1.run()

    # crash at step 6, then restart from LATEST (step 4)
    t2 = Trainer(model, ocfg,
                 LoopConfig(total_steps=12, ckpt_every=4, log_every=100,
                            fail_at_step=6), str(tmp_path / "b"),
                 _batch_fn(cfg))
    with pytest.raises(SimulatedFailure):
        t2.run()
    t3 = trainer(tmp_path / "b")   # no fail_at_step: resumes at 4
    step, p_resumed, _, m_res = t3.run()
    assert step == 12
    flat1 = jax.tree.leaves(p_ref)
    flat2 = jax.tree.leaves(p_resumed)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoints are mesh-independent: train on an 8-device mesh, lose
    half the hosts, restore onto a 4-device mesh and continue. Runs in a
    subprocess so the host-device-count flag stays scoped (conftest must
    see 1 device)."""
    import subprocess
    import sys
    import textwrap
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import registry
        from repro.models.lm import LM
        from repro.checkpoint.ckpt import CheckpointManager
        from repro.sharding import specs as sh

        cfg = registry.get_smoke_config("qwen2-0.5b")
        cfg = dataclasses.replace(cfg, num_layers=2, d_ff=64)
        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cm = CheckpointManager(r"{tmp_path}")
        cm.save(3, params)

        def mesh_of(n):
            from repro.compat import make_mesh
            return make_mesh((n // 2, 2), ("data", "model"))

        for ndev in (8, 4):     # full fleet, then degraded fleet
            mesh = mesh_of(ndev)
            shards = sh.param_shardings(cfg, mesh, jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0))))
            step, restored = cm.restore(like=params, shardings=shards)
            batch = {{"tokens": jnp.zeros((4, 8), jnp.int32)}}
            with mesh:
                loss, _ = jax.jit(model.loss)(restored, batch)
            assert jnp.isfinite(loss), ndev
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env={**__import__("os").environ,
                                       "PYTHONPATH": "src"},
                       cwd=str(pathlib_root()), timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


def pathlib_root():
    import pathlib
    return pathlib.Path(__file__).resolve().parents[1]


def test_straggler_rebalancing_moves_shards():
    router = BwapDataRouter(num_shards=64, host_bws=[1.0, 1.0, 1.0, 1.0])
    before = router.shards_of(3).size
    # host 3 is 5x slower than the others
    for _ in range(12):
        for h in range(4):
            router.record_fetch(h, 0.05 if h != 3 else 0.25)
    after = router.shards_of(3).size
    assert after < before
    assert router.migrations > 0
    # all shards still owned exactly once
    assert sum(router.shards_of(h).size for h in range(4)) == 64


def test_prefetch_loader_yields_deterministic_batches():
    ds = ShardedTokenDataset(vocab_size=97, seq_len=8, num_shards=4, seed=1)
    router = BwapDataRouter(4, [1, 1, 1, 1])
    loader = PrefetchLoader(ds, router, host=0, batch_size=2)
    s1, b1 = next(loader)
    loader.close()
    b_again = ds.batch(int(router.shards_of(0)[s1 % router.shards_of(0).size]
                           ) if len(router.shards_of(0)) else 0, s1, 2)
    assert b1.shape == (2, 8)
    assert b1.dtype == np.int32


def test_grad_compression_error_feedback():
    """int8 psum with error feedback: single-step error is bounded; the
    residual carries what was rounded away."""
    from repro.compat import make_mesh
    from repro.train import compress
    mesh = make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(64, 64)).astype(np.float32))}
    r = compress.init_residuals(g)
    red, new_r = compress.compressed_psum_grads(g, r, mesh)
    err = np.abs(np.asarray(red["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127.0
    assert err.max() <= scale * 1.01
    # error feedback: residual == what was lost
    np.testing.assert_allclose(np.asarray(new_r["w"]),
                               np.asarray(g["w"]) - np.asarray(red["w"]),
                               rtol=1e-5, atol=1e-6)


def test_quantized_adam_moments_roundtrip():
    from repro.train.optimizer import dequantize_q8, quantize_q8
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1000,))
                    .astype(np.float32))
    q = quantize_q8(x, 256)
    back = dequantize_q8(q, x.shape)
    err = np.abs(np.asarray(back) - np.asarray(x))
    # blockwise absmax: error bounded by scale/2 per block
    assert err.max() < np.abs(np.asarray(x)).max() / 127.0
