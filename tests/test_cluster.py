"""Disaggregated serving cluster (DESIGN.md §13): Eq.-1 link rows, the
Eq.-5-striped interconnect and its virtual clock, the chunked page
channel (wire round-trips, drift billing, convert-on-import), and the
prefill/decode router's token identity + saturation fallback."""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.cluster import (ClusterRouter, Interconnect, Link, PageChannel,
                           convert_range)
from repro.configs import registry
from repro.core import bwmodel
from repro.core.dwp import DWPConfig
from repro.obs.observatory import Observatory
from repro.placement.fabric import as_view
from repro.placement.persist import (PersistentTier, deserialize_range,
                                     kv_layout_metadata, serialize_range)
from repro.placement.pool import BwapPagePool, MemoryDomain
from repro.scheduler import RequestScheduler
from repro.serve.engine import ServeEngine

CHAT = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                           num_layers=1, compute_dtype="float32")


@pytest.fixture(scope="module")
def params():
    import jax
    from repro.models.lm import LM
    return LM(CHAT).init(jax.random.PRNGKey(0))


def _host(cfg=CHAT, *, page_size=4, pages=96, obs=False, **tier_kw):
    pool = BwapPagePool(cfg, [
        MemoryDomain("hbm_local", pages // 2, 819.0, True),
        MemoryDomain("host_dram", pages - pages // 2, 0.016, False),
    ], page_size=page_size, dwp_config=DWPConfig(n=10 ** 6, c=1))
    view = as_view(pool)
    tier_kw.setdefault("bw_gbps", 8.0)
    tier_kw.setdefault("capacity_pages", 256)
    tier = PersistentTier(**tier_kw)
    view.fabric.attach_persist(tier)
    ob = Observatory(pool) if obs else None
    return pool, view, tier, ob


def _fill(pool, pid, val):
    pool.k_pool = pool.k_pool.at[:, pid].set(float(val))
    pool.v_pool = pool.v_pool.at[:, pid].set(float(-val))


def _chain(view, pool, tokens, val=5):
    pages = []
    for i in range(len(tokens) // pool.page_size):
        view.append_page(pages)
        _fill(pool, pages[-1], val + i)
    view.register_prefix(list(tokens), pages, len(tokens))
    return pages


def _wire(*links, **kw):
    links = links or (Link("nvl", 0.2, 1e-4), Link("rdma", 0.05, 5e-4))
    return Interconnect(list(links), **kw)


# ---------------------------------------------------------------------------
# Eq. 1 with link rows
# ---------------------------------------------------------------------------

def test_stall_cost_link_rows():
    b, bw = np.array([8e9]), np.array([8.0])
    # a slow link row dominates: 8e9 B / 0.8 GB/s + 0.5s latency
    assert bwmodel.stall_cost(
        b, bw, link_bytes=np.array([8e9]), link_bw_gbps=np.array([0.8]),
        link_latency_s=np.array([0.5])) == pytest.approx(10.5)
    # a fast link never dominates a slow domain row
    assert bwmodel.stall_cost(
        b, bw, link_bytes=np.array([8e9]), link_bw_gbps=np.array([800.0]),
        link_latency_s=np.array([0.0])) == pytest.approx(1.0)
    # zero-byte link rows contribute neither time nor latency
    assert bwmodel.stall_cost(
        b, bw, link_bytes=np.array([0.0]), link_bw_gbps=np.array([0.8]),
        link_latency_s=np.array([9.9])) == pytest.approx(1.0)
    # links compose with the tier row under the same max
    assert bwmodel.stall_cost(
        b, bw, tier_bytes=8e9, tier_bw_gbps=0.4,
        link_bytes=np.array([8e9]), link_bw_gbps=np.array([0.8]),
        link_latency_s=np.array([0.0])) == pytest.approx(20.0)
    # an empty domain vector prices a pure wire transfer
    assert bwmodel.stall_cost(
        np.zeros(0), np.zeros(0), link_bytes=np.array([1e9]),
        link_bw_gbps=np.array([1.0]),
        link_latency_s=np.array([0.25])) == pytest.approx(1.25)


# ---------------------------------------------------------------------------
# interconnect: Eq.-5 striping, virtual clock, calibration
# ---------------------------------------------------------------------------

def test_interconnect_weights_follow_bandwidth():
    ic = _wire(Link("a", 0.3), Link("b", 0.1))
    w = ic.weights()
    assert w == pytest.approx([0.75, 0.25])
    per = ic.stripe(1000)
    assert per.sum() == 1000
    assert per[0] == pytest.approx(750, abs=1)


def test_interconnect_price_is_slowest_stripe():
    ic = _wire(Link("a", 0.3, 1e-3), Link("b", 0.1, 4e-3))
    n = 300_000
    per = ic.stripe(n)
    want = max(per[0] / 0.3e9 + 1e-3, per[1] / 0.1e9 + 4e-3)
    assert ic.transfer_seconds(n) == pytest.approx(want)
    # proportional striping beats a uniform split on asymmetric links
    uniform = bwmodel.stall_cost(
        np.zeros(0), np.zeros(0), link_bytes=np.array([n / 2, n / 2]),
        link_bw_gbps=np.array([0.3, 0.1]),
        link_latency_s=np.array([1e-3, 4e-3]))
    assert ic.transfer_seconds(n) < uniform


def test_interconnect_virtual_clock_serializes_sends():
    ic = _wire(Link("a", 0.1))
    s0, d0 = ic.send(100_000, now=0.0)
    s1, d1 = ic.send(100_000, now=0.0)
    assert s0 == 0.0 and s1 == pytest.approx(d0)
    assert ic.busy_until == pytest.approx(d0 + d1)
    assert ic.queue_delay(0.0) == pytest.approx(d0 + d1)
    assert ic.saturated(0.0, horizon_s=d0) \
        and not ic.saturated(d0 + d1, horizon_s=0.0)


def test_interconnect_calibration_moves_effective_bw():
    ic = _wire(Link("a", 0.1))
    predicted = ic.transfer_seconds(1_000_000)
    ic.calibrate(1_000_000, measured_s=predicted * 2)    # wire is slower
    assert ic.bw_effective[0] < 0.1
    slow = ic.transfer_seconds(1_000_000)
    assert slow > predicted
    ic.calibrate(1_000_000, measured_s=slow / 4)         # now faster
    assert ic.transfer_seconds(1_000_000) < slow
    assert ic.calibration_samples == 2


# ---------------------------------------------------------------------------
# page channel: wire round-trip, events, drift billing
# ---------------------------------------------------------------------------

def test_channel_roundtrip_same_geometry():
    pool_a, view_a, _, _ = _host()
    pool_b, view_b, _, _ = _host()
    toks = list(range(100, 112))
    pages = _chain(view_a, pool_a, toks, val=7)
    orig_k = np.asarray(pool_a.k_pool[:, pages]).copy()

    events = []
    for ev in ("link_send", "link_recv"):
        view_a.fabric.subscribe(ev, lambda event=ev, **kw:
                                events.append((event, kw)))
        view_b.fabric.subscribe(ev, lambda event=ev, **kw:
                                events.append((event, kw)))
    ch = PageChannel(_wire(), chunk_bytes=4096)
    parcel = ch.send(view_a, pages, now=0.0, tokens=toks, ntokens=len(toks))
    assert parcel.chunks == -(-len(parcel.data) // 4096) and parcel.chunks > 1
    assert parcel.arrive_s > 0.0
    new_ids, parcel2, secs = ch.recv(view_b)
    assert parcel2 is parcel and secs > 0.0
    assert ch.converted_imports == 0
    assert np.array_equal(np.asarray(pool_b.k_pool[:, new_ids]), orig_k)

    # the peer's trie serves the imported chain
    got = []
    n = view_b.probe_prefix(toks, got, count=False)
    assert n == len(toks) and got == new_ids
    view_b.release(got)

    kinds = [e for e, _ in events]
    assert kinds == ["link_send", "link_recv"]
    assert events[0][1]["bytes"] == len(parcel.data)
    assert events[0][1]["chunks"] == parcel.chunks
    assert events[1][1]["pages"] == len(new_ids)

    # both byte ledgers balance: exporter keeps its copy, importer pays own
    view_b.release(new_ids)
    view_a.fabric.check_invariants()
    view_b.fabric.check_invariants()


def test_channel_observatory_counters_and_drift_billing():
    pool_a, view_a, _, obs_a = _host(obs=True)
    pool_b, view_b, _, obs_b = _host(obs=True)
    pages = _chain(view_a, pool_a, list(range(8)), val=3)

    ic = _wire(Link("a", 0.1))
    measured = {"s": None}

    def probe(kind, nbytes):
        assert kind == "link_transfer"
        measured["s"] = ic.transfer_seconds(nbytes) * 2.0
        return measured["s"]

    ch = PageChannel(ic, chunk_bytes=1 << 14, probe=probe)
    parcel = ch.send(view_a, pages, now=0.0, tokens=list(range(8)),
                     ntokens=8)
    new_ids, _, _ = ch.recv(view_b)

    m = obs_a.metrics
    assert m.get("repro_link_bytes_total").value(
        view_a.name, "send") == len(parcel.data)
    assert m.get("repro_link_chunks_total").value(
        view_a.name) == parcel.chunks
    assert obs_b.metrics.get("repro_link_bytes_total").value(
        view_b.name, "recv") == len(parcel.data)
    # the measured wire time landed in the drift ledger and calibration
    assert len(obs_a.drift.ratio["link_transfer"]) == 1
    assert obs_a.drift.ratio["link_transfer"].last() == pytest.approx(2.0)
    assert ic.calibration_samples == 1 and ic.bw_effective[0] < 0.1
    view_b.release(new_ids)


def test_channel_convert_on_import_is_token_exact():
    pool_a, view_a, _, _ = _host(page_size=4)
    pool_b, view_b, _, _ = _host(page_size=8)
    toks = list(range(200, 214))                 # 14 tokens: partial tail
    pages = []
    for _ in range(4):                           # 4 src pages hold 14 valid
        view_a.append_page(pages)
    rng = np.random.default_rng(0)
    kb = rng.standard_normal(pool_a.k_pool[:, pages].shape).astype(
        np.asarray(pool_a.k_pool).dtype)
    vb = rng.standard_normal(pool_a.v_pool[:, pages].shape).astype(
        np.asarray(pool_a.v_pool).dtype)
    pool_a.k_pool = pool_a.k_pool.at[:, pages].set(kb)
    pool_a.v_pool = pool_a.v_pool.at[:, pages].set(vb)
    view_a.register_prefix(toks[:12], pages[:3], 12)

    ch = PageChannel(_wire(), chunk_bytes=1 << 15)
    ch.send(view_a, pages, now=0.0, tokens=toks, ntokens=14)
    new_ids, _, _ = ch.recv(view_b)
    assert ch.converted_imports == 1
    assert len(new_ids) == 2                     # ceil(14 / 8)

    def tokview(arr, npages, ps):                # [L, P, ps, ...] -> tokens
        a = np.asarray(arr)
        return a.reshape(a.shape[0], npages * ps, *a.shape[3:])

    got_k = tokview(pool_b.k_pool[:, new_ids], 2, 8)[:, :14]
    got_v = tokview(pool_b.v_pool[:, new_ids], 2, 8)[:, :14]
    assert np.array_equal(got_k, tokview(kb, 4, 4)[:, :14])
    assert np.array_equal(got_v, tokview(vb, 4, 4)[:, :14])

    # chain keys rebuilt over full destination pages only: 14 // 8 = 1
    got = []
    n = view_b.probe_prefix(toks, got, count=False)
    assert n == 8 and got == new_ids[:1]
    view_b.release(got)
    view_b.release(new_ids)
    view_a.fabric.check_invariants()
    view_b.fabric.check_invariants()


# ---------------------------------------------------------------------------
# convert_range unit behaviour
# ---------------------------------------------------------------------------

def test_convert_layout_only_mismatch_restamps():
    pool, view, tier, _ = _host()
    pages = _chain(view, pool, list(range(8)))
    blob = deserialize_range(serialize_range(
        tier.export_range(view, pages, tokens=list(range(8)), ntokens=8)))
    other = kv_layout_metadata(pool.cfg, pool.page_size, None)
    other = dict(other, mesh_axes={"data": 8, "model": 1})
    out = convert_range(blob, geometry=tier._geometry(pool), layout=other)
    assert out["layout"] == other
    assert np.array_equal(out["k"], blob["k"])   # bytes untouched
    assert "converted" not in out


def test_convert_raises_on_per_token_mismatch():
    pool, view, tier, _ = _host()
    pages = _chain(view, pool, list(range(8)))
    blob = tier.export_range(view, pages, tokens=list(range(8)), ntokens=8)
    bad = dict(tier._geometry(pool))
    bad["num_layers"] = bad["num_layers"] + 1
    with pytest.raises(ValueError, match="recompute, not a re-layout"):
        convert_range(blob, geometry=bad, layout=blob["layout"])
    bad = dict(tier._geometry(pool), page_size=8)
    bad["k_block"] = [8, 99, 99]
    with pytest.raises(ValueError, match="k_block tail"):
        convert_range(blob, geometry=bad, layout=blob["layout"])


# ---------------------------------------------------------------------------
# the router: token identity, overlap, fallback
# ---------------------------------------------------------------------------

def _engine(pool, params, *, max_batch=8):
    sched = RequestScheduler(pool, max_batch=max_batch,
                             prefill_token_budget=32, default_max_new=8)
    return ServeEngine(CHAT, params, pool, scheduler=sched,
                       wall_clock=False, sim_step_s=0.005)


def _oracle(params, prompts, max_new):
    pool, _, _, _ = _host(page_size=8, pages=128)
    eng = _engine(pool, params)
    for p in prompts:
        eng.submit(list(p), max_new=max_new)
    steps = 0
    while (eng.active or eng.waiting) and steps < 2000:
        eng.step()
        steps += 1
    return [list(s.tokens) for s in sorted(eng.finished,
                                           key=lambda s: s.sid)]


def test_router_disagg_token_identity(params):
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, CHAT.vocab_size, n).tolist()
               for n in (12, 17, 9, 20)]
    oracle = _oracle(params, prompts, max_new=6)

    pool_p, view_p, _, _ = _host(page_size=4, pages=128)
    pool_d, view_d, _, _ = _host(page_size=8, pages=128)
    ch = PageChannel(_wire(), chunk_bytes=8192)
    router = ClusterRouter(_engine(pool_p, params), _engine(pool_d, params),
                           ch, saturation_horizon_s=10.0)
    rids = [router.submit(list(p), max_new=6) for p in prompts]
    router.drain()
    assert [router.result(r) for r in rids] == oracle
    assert router.handoffs == len(prompts) and router.fallbacks == 0
    assert ch.converted_imports == len(prompts)   # ps 4 -> 8 every handoff
    s = router.summary()
    assert s["tokens"] == 6 * len(prompts)        # head token counted once
    assert s["ttft_mean_s"] > 0 and s["ttft_weighted_goodput"] > 0
    view_p.fabric.check_invariants()
    view_d.fabric.check_invariants()


def test_router_saturated_wire_falls_back_to_single_host(params):
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, CHAT.vocab_size, 10).tolist()
               for _ in range(3)]
    oracle = _oracle(params, prompts, max_new=5)

    pool_p, _, _, _ = _host(page_size=4, pages=128)
    pool_d, _, _, _ = _host(page_size=8, pages=128)
    ic = _wire(Link("thin", 1e-6))               # ~nothing gets through
    ic.send(10_000_000, now=0.0)                 # pre-existing backlog
    router = ClusterRouter(_engine(pool_p, params), _engine(pool_d, params),
                           PageChannel(ic), saturation_horizon_s=0.01)
    rids = [router.submit(list(p), max_new=5) for p in prompts]
    router.drain()
    assert router.fallbacks == len(prompts) and router.handoffs == 0
    assert [router.result(r) for r in rids] == oracle


def test_router_short_requests_serve_locally(params):
    pool_p, _, _, _ = _host(page_size=4)
    pool_d, _, _, _ = _host(page_size=8)
    router = ClusterRouter(_engine(pool_p, params), _engine(pool_d, params),
                           PageChannel(_wire()), saturation_horizon_s=10.0)
    rid = router.submit([3, 17, 29, 5], max_new=1)   # nothing to hand off
    router.drain()
    assert router.fallbacks == 1 and router.handoffs == 0
    assert len(router.result(rid)) == 5
