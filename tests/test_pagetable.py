"""Logical page-table layer (ISSUE 3 / DESIGN.md §6): refcount lifecycle,
prefix-trie hit/miss, CoW fork exactness, swap pinning of shared pages, and
O(n) incremental chunked prefill vs the recompute oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:      # bare env: property tests skip individually
    from _hypothesis_stub import given, settings, st

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.kernels.paged_attention import ops as paged_ops
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_prefill_attention_ref)
from repro.scheduler import KVSwapManager, RequestScheduler
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain


@pytest.fixture(scope="module")
def small_lm():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    from repro.models.lm import LM
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, params


def _pool(cfg, fast=32, peer=16, host=16, page_size=4):
    domains = [
        MemoryDomain("hbm_local", fast, 819.0, True),
        MemoryDomain("hbm_peer", peer, 0.05, False),
        MemoryDomain("host", host, 0.016, False),
    ]
    return BwapPagePool(cfg, domains, page_size=page_size,
                        dwp_config=DWPConfig(n=10 ** 6, c=1))


def _drain(eng, max_steps=500):
    steps = 0
    while (eng.active or eng.waiting) and steps < max_steps:
        eng.step()
        steps += 1
    return steps


# ---------------------------------------------------------------------------
# refcounts + trie
# ---------------------------------------------------------------------------

def test_refcount_lifecycle(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg)
    t = pool.table
    ps = pool.page_size
    tokens = list(range(1, 1 + 3 * ps))          # 3 full blocks
    donor: list = []
    t.grow(donor, 3)
    assert all(t.ref[p] == 1 for p in donor)
    t.register_prefix(tokens, donor, len(tokens))
    assert t.stats()["trie_nodes"] == 3

    view: list = []
    assert t.match_prefix(tokens, view) == 3 * ps
    assert view == donor
    assert all(t.ref[p] == 2 for p in donor)
    assert t.exclusive(view) == []               # everything shared
    assert t.stats()["shared_pages"] == 3
    assert t.stats()["saved_pages"] == 3

    free0 = pool.free_count()
    t.release(view)                              # drop one holder
    assert pool.free_count() == free0            # donor still holds
    assert all(t.ref[p] == 1 for p in donor)
    t.release(donor)                             # last holder: pages free,
    assert pool.free_count() == free0 + 3        # trie nodes gone
    assert t.stats()["trie_nodes"] == 0
    assert t.ref == {}


def test_trie_chain_keying_blocks_position_aliasing(small_lm):
    """An identical token block after a *different* prefix must not match:
    K/V depends on the whole preceding context, so trie keys chain."""
    cfg, _ = small_lm
    pool = _pool(cfg)
    t = pool.table
    ps = pool.page_size
    blk_a, blk_b = list(range(10, 10 + ps)), list(range(50, 50 + ps))
    donor: list = []
    t.grow(donor, 2)
    t.register_prefix(blk_a + blk_b, donor, 2 * ps)

    hit: list = []
    assert t.match_prefix(blk_a + blk_b, hit) == 2 * ps      # full chain
    t.release(hit)
    partial: list = []
    assert t.match_prefix(blk_a + list(range(90, 90 + ps)),
                          partial) == ps                     # prefix only
    t.release(partial)
    aliased: list = []
    # blk_b exists in the trie, but only as a *child* of blk_a's node:
    # leading with it must miss
    assert t.match_prefix(blk_b + blk_a, aliased) == 0
    assert t.stats()["prefix_misses"] >= 1
    t.release(donor)


def test_fork_for_write_isolates_holders(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg)
    t = pool.table
    a: list = []
    t.grow(a, 1)
    pool.k_pool = pool.k_pool.at[:, a[0]].set(7.0)
    pool.v_pool = pool.v_pool.at[:, a[0]].set(-7.0)
    tokens = list(range(1, 1 + pool.page_size))
    t.register_prefix(tokens, a, pool.page_size)
    b: list = []
    t.match_prefix(tokens, b)
    assert b == a

    pid = t.fork_for_write(b, 0)                 # CoW: b gets a clone
    assert pid != a[0] and b[0] == pid
    assert t.ref[a[0]] == 1 and t.ref[pid] == 1
    assert t.cow_faults == 1
    # clone carries the bytes; writes to it don't touch the original
    assert (np.asarray(pool.k_pool)[:, pid] == 7.0).all()
    pool.k_pool = pool.k_pool.at[:, pid].set(9.0)
    assert (np.asarray(pool.k_pool)[:, a[0]] == 7.0).all()
    # forking an exclusive page is a no-op
    assert t.fork_for_write(b, 0) == pid and t.cow_faults == 1
    t.release(a)
    t.release(b)
    assert pool.free_count() == pool.total_pages


# ---------------------------------------------------------------------------
# engine integration: sharing is invisible in tokens, visible in footprint
# ---------------------------------------------------------------------------

def _shared_prompts(cfg, ps, prefix_blocks=2, n=3, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, prefix_blocks * ps).tolist()
    return [prefix + rng.integers(1, cfg.vocab_size, 3 + i).tolist()
            for i in range(n)]


def _run_engine(cfg, params, prompts, *, reuse, incremental=True,
                max_new=5, budget=64, arrivals=None):
    pool = _pool(cfg, fast=64, peer=16, host=16)
    sched = RequestScheduler(pool, max_batch=8,
                             prefill_token_budget=budget,
                             default_max_new=max_new)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False,
                      sim_step_s=0.01, prefix_reuse=reuse,
                      incremental_prefill=incremental)
    for i, p in enumerate(prompts):
        eng.submit(list(p), arrival_s=arrivals[i] if arrivals else None)
    _drain(eng)
    assert len(eng.finished) == len(prompts)
    return eng, pool


def test_prefix_sharing_saves_pages_tokens_identical(small_lm):
    """Requests sharing a prompt prefix must generate the same tokens as
    without sharing, while mapping the prefix onto shared physical pages."""
    cfg, params = small_lm
    prompts = _shared_prompts(cfg, ps=4, prefix_blocks=2, n=3)
    # staggered arrivals: the donor's prefix registers (end of its prefill
    # step) before the matchers' first planning probes, and every holder
    # chain overlaps a live sequence so the trie pages stay resident
    arrivals = [0.0, 0.02, 0.04]
    on, pool_on = _run_engine(cfg, params, prompts, reuse=True,
                              arrivals=arrivals)
    off, _ = _run_engine(cfg, params, prompts, reuse=False,
                         arrivals=arrivals)
    tok_on = {s.sid: s.tokens for s in on.finished}
    tok_off = {s.sid: s.tokens for s in off.finished}
    assert tok_on == tok_off
    st_ = pool_on.table.stats()
    assert st_["prefix_hit_pages"] >= 2 * 2      # 2 matchers x 2 blocks
    assert on.prefill_tokens_computed < off.prefill_tokens_computed
    # all pages reclaimed at the end — sharing never leaks
    assert pool_on.free_count() == pool_on.total_pages


def test_cow_fork_on_full_prompt_match_is_exact(small_lm):
    """A prompt fully covered by registered blocks: the first decode step
    writes the last prompt position *into a shared page* — the CoW fork —
    and generation must equal the unshared baseline."""
    cfg, params = small_lm
    ps = 4
    rng = np.random.default_rng(3)
    prefix = rng.integers(1, cfg.vocab_size, 3 * ps).tolist()
    donor = prefix + rng.integers(1, cfg.vocab_size, 5).tolist()
    matcher = list(prefix)                       # block-aligned full prompt

    base, _ = _run_engine(cfg, params, [matcher], reuse=False)
    eng, pool = _run_engine(cfg, params, [donor, matcher], reuse=True,
                            arrivals=[0.0, 0.015])
    assert pool.table.cow_faults >= 1            # the fork actually fired
    got = next(s for s in eng.finished if s.prompt_len == len(matcher))
    want = base.finished[0]
    assert got.tokens[got.prompt_len:] == want.tokens[want.prompt_len:]
    assert pool.free_count() == pool.total_pages


# ---------------------------------------------------------------------------
# incremental chunked prefill: O(n) compute, token-exact vs recompute
# ---------------------------------------------------------------------------

def test_incremental_prefill_is_o_n_and_token_exact(small_lm):
    """With a small chunk budget the recompute path forwards O(n²) prompt
    tokens across chunks; the incremental path must forward each prompt
    token exactly once and produce identical generations."""
    cfg, params = small_lm
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (23, 17, 9)]
    targets = sum(len(p) - 1 for p in prompts)
    inc, _ = _run_engine(cfg, params, prompts, reuse=False,
                         incremental=True, budget=6)
    rec, _ = _run_engine(cfg, params, prompts, reuse=False,
                         incremental=False, budget=6)
    assert {s.sid: s.tokens for s in inc.finished} \
        == {s.sid: s.tokens for s in rec.finished}
    # the O(n) assertion: exactly one forward per materialized position
    assert inc.prefill_tokens_computed == targets
    # the recompute oracle re-forwards the prefix every chunk: O(n²)
    assert rec.prefill_tokens_computed > targets
    assert inc.prefill_chunks_run > len(prompts)     # chunking did happen


# ---------------------------------------------------------------------------
# prefill-mode paged attention op
# ---------------------------------------------------------------------------

def test_prefill_op_matches_decode_op_per_position():
    """The prefill-mode op at chunk [lo, hi) must agree with the decode op
    queried position-by-position (lens = pos+1) over the same pool."""
    ps, pages, nkv, g, h, t, lo = 4, 8, 2, 2, 16, 5, 6
    nq = nkv * g
    kp = jax.random.normal(jax.random.PRNGKey(0), (pages, ps, nkv, h))
    vp = jax.random.normal(jax.random.PRNGKey(1), (pages, ps, nkv, h))
    q = jax.random.normal(jax.random.PRNGKey(2), (t, nq, h))
    tbl = jnp.asarray([3, 1, 4], jnp.int32)      # covers lo + t = 11 < 12
    out = paged_prefill_attention_ref(q, kp, vp, tbl, lo)
    per_pos = paged_attention_ref(
        q, kp, vp, jnp.broadcast_to(tbl, (t, 3)),
        lo + 1 + jnp.arange(t, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(per_pos),
                               rtol=1e-6, atol=1e-6)


def test_prefill_kernel_matches_ref_interpret():
    """Pallas prefill kernel (interpret mode) vs the jnp oracle."""
    ps, pages, nkv, g, h, t, lo = 4, 8, 2, 2, 16, 5, 6
    nq = nkv * g
    kp = jax.random.normal(jax.random.PRNGKey(0), (pages, ps, nkv, h))
    vp = jax.random.normal(jax.random.PRNGKey(1), (pages, ps, nkv, h))
    q = jax.random.normal(jax.random.PRNGKey(2), (t, nq, h))
    tbl = jnp.asarray([3, 1, 4], jnp.int32)
    ref = paged_prefill_attention_ref(q, kp, vp, tbl, lo)
    try:
        out = paged_ops.paged_prefill_attention(q, kp, vp, tbl, lo,
                                                impl="pallas",
                                                interpret=True)
    except Exception as e:                        # pragma: no cover
        pytest.skip(f"pallas interpret unavailable: {e}")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# swap x sharing: shared pages pin, exclusive pages park
# ---------------------------------------------------------------------------

def test_swap_roundtrip_pins_shared_pages(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, fast=8, peer=12, host=12)
    t = pool.table
    ps = pool.page_size
    swap = KVSwapManager(pool, reserve_fraction=0.8)
    tokens = list(range(1, 1 + 2 * ps))
    donor: list = []
    t.grow(donor, 2)
    for i, p in enumerate(donor):
        pool.k_pool = pool.k_pool.at[:, p].set(float(i + 1))
    t.register_prefix(tokens, donor, 2 * ps)
    victim: list = []
    t.match_prefix(tokens, victim)               # 2 shared pages
    t.grow(victim, 2)                            # + 2 exclusive pages
    for i in (2, 3):
        pool.k_pool = pool.k_pool.at[:, victim[i]].set(float(10 + i))
    shared_before = victim[:2]

    out_pages, secs = swap.swap_out(list(victim), table=t)
    assert out_pages[:2] == shared_before        # pinned in place
    assert out_pages[2] != victim[2] and out_pages[3] != victim[3]
    assert swap.parked_count(out_pages) == 2
    for i, p in enumerate(out_pages):            # refs followed the bytes
        assert t.ref[p] == (2 if i < 2 else 1)
    assert (np.asarray(pool.k_pool)[:, out_pages[2]] == 12.0).all()

    back, _ = swap.swap_in(out_pages, table=t)
    assert back[:2] == shared_before
    assert swap.parked_count(back) == 0
    assert swap.slots_free() == swap.reserved_total
    assert (np.asarray(pool.k_pool)[:, back[2]] == 12.0).all()
    assert (np.asarray(pool.k_pool)[:, back[3]] == 13.0).all()
    assert (np.asarray(pool.k_pool)[:, back[0]] == 1.0).all()
    t.release(back)
    t.release(donor)
    assert pool.free_count() + swap.reserved_total == pool.total_pages


def test_oversubscribed_shared_prefix_completes(small_lm):
    """Preemption under sharing: a pool that only fits the workload through
    both swap *and* prefix sharing completes with token-exact results."""
    cfg, params = small_lm
    ps = 4
    rng = np.random.default_rng(5)
    prefix = rng.integers(1, cfg.vocab_size, 3 * ps).tolist()
    prompts = [prefix + rng.integers(1, cfg.vocab_size, 2 + i).tolist()
               for i in range(5)]
    arrivals = [0.0] + [0.05 + 0.01 * i for i in range(4)]

    def run(fast, peer, host, swap_on):
        pool = _pool(cfg, fast=fast, peer=peer, host=host)
        swap = KVSwapManager(pool, reserve_fraction=0.9) if swap_on else None
        sched = RequestScheduler(pool, max_batch=4, prefill_token_budget=24,
                                 default_max_new=12, swap=swap)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.01)
        for p, a in zip(prompts, arrivals):
            eng.submit(list(p), arrival_s=a)
        _drain(eng)
        assert len(eng.finished) == len(prompts)
        return ({s.sid: s.tokens for s in eng.finished},
                pool.telemetry.swap_outs, pool.table.prefix_hit_pages)

    ref, _, _ = run(64, 16, 16, swap_on=False)       # roomy baseline
    got, swaps, hits = run(8, 10, 22, swap_on=True)   # pressured + shared
    assert swaps > 0 and hits > 0                # both mechanisms engaged
    assert got == ref


# ---------------------------------------------------------------------------
# property test: random share/fork/swap/free interleavings
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 5)),
                min_size=1, max_size=24),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_random_share_fork_swap_interleavings(ops, seed):
    """Random interleavings of share / CoW-fork / swap round-trip / release
    never cross-wire contents, leak pages, or corrupt refcounts."""
    cfg = dataclasses.replace(registry.get_smoke_config("qwen2-0.5b"),
                              num_layers=1, compute_dtype="float32")
    pool = _pool(cfg, fast=10, peer=12, host=14)
    t = pool.table
    ps = pool.page_size
    swap = KVSwapManager(pool, reserve_fraction=0.7)
    rng = np.random.default_rng(seed)
    # a small pool of recurring token streams: identical streams are what
    # makes match_prefix actually share pages between views
    streams = [[int(x) for x in rng.integers(1, 10 ** 6, 2 * ps)]
               for _ in range(3)]
    views: list[dict] = []
    next_fill = [1.0]

    def fill_page(pid, val):
        pool.k_pool = pool.k_pool.at[:, pid].set(val)

    def new_view():
        n = int(rng.integers(1, 3))
        tokens = streams[int(rng.integers(len(streams)))][:n * ps]
        pages: list = []
        matched = t.match_prefix(tokens, pages) // ps
        content = [None] * n
        for b in range(matched):
            content[b] = None                    # resolved via donor below
        for b in range(matched, n):
            t.append_page(pages)
            v = next_fill[0]
            next_fill[0] += 1.0
            fill_page(pages[b], v)
            content[b] = v
        # matched blocks inherit the registered content values
        for b in range(matched):
            content[b] = float(np.asarray(pool.k_pool)[0, pages[b], 0, 0, 0])
        t.register_prefix(tokens, pages, n * ps)
        views.append({"pages": pages, "content": content, "parked": False})

    for op, which in ops:
        if op == 0 or not views:
            if pool.free_count() >= 3:
                new_view()
            continue
        s = views[which % len(views)]
        if op == 1 and not s["parked"]:          # CoW fork + private write
            idx = int(rng.integers(len(s["pages"])))
            if pool.free_count() < 1:
                continue
            t.fork_for_write(s["pages"], idx)
            v = next_fill[0]
            next_fill[0] += 1.0
            fill_page(s["pages"][idx], v)
            s["content"][idx] = v
        elif op == 2:                            # swap round-trip leg
            if s["parked"]:
                if pool.free_count() >= swap.parked_count(s["pages"]):
                    s["pages"], _ = swap.swap_in(s["pages"], table=t)
                    s["parked"] = False
            else:
                excl = len(t.exclusive(s["pages"]))
                if swap.can_swap_out(excl):
                    s["pages"], _ = swap.swap_out(s["pages"], table=t)
                    s["parked"] = True
        elif op == 3 and not s["parked"]:        # release
            t.release(s["pages"])
            views.remove(s)

    # invariants: contents intact, refcounts = holder counts, no leaks
    holder_counts: dict[int, int] = {}
    for s in views:
        for pid, val in zip(s["pages"], s["content"]):
            holder_counts[pid] = holder_counts.get(pid, 0) + 1
            got = np.asarray(pool.k_pool)[0, pid, 0, 0, 0]
            assert got == val, f"page {pid}: {got} != {val}"
    for pid, n in holder_counts.items():
        assert t.ref[pid] == n
    assert sum(t.ref.values()) == sum(len(s["pages"]) for s in views)
    live = len(t.ref)
    parked = sum(swap.parked_count(s["pages"]) for s in views)
    assert pool.free_count() + swap.reserved_total + live - parked \
        == pool.total_pages
