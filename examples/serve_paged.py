"""End-to-end serving driver: scheduler-paced requests over the BWAP pool.

Oversubscribed by construction: the trace's total KV footprint exceeds
``hbm_local`` (and the unreserved pool), so completion *requires* the
scheduler's preemption path — cold sequences park in BWAP-weighted slow
domains (reserved swap slots) and resume later. Priority classes
("interactive" with tight deadlines, "batch" without) drive victim
selection; the run ends with a per-class SLO summary.

    PYTHONPATH=src python examples/serve_paged.py [--requests 10] [--new 12]

``--restart-demo`` runs the persistence-tier walkthrough instead: pin a
system preamble, export it to the on-disk prefix store, tear the whole
fabric down, and re-import into a fresh engine — the first request after
the "restart" hits the restored trie instead of re-prefilling.

``--trace-out PATH`` attaches the fabric observatory (DESIGN.md §10) and
dumps the run as Chrome/Perfetto trace-event JSON: open ui.perfetto.dev,
"Open trace file", pick the JSON — one track per request (admit, queued,
prefill chunks, decode steps, swap_out/swap_in) on the virtual clock.
Tracing never changes the decoded tokens.
"""

import argparse
import dataclasses
import pathlib

import jax
import numpy as np

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.scheduler import (KVSwapManager, PriorityClass, RequestScheduler,
                             SloSpec, WorkloadSpec, generate, total_kv_pages)
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain


def restart_demo(cfg, params, seed: int) -> None:
    """Restart-surviving prefix store, end to end (DESIGN.md §9)."""
    from repro.placement.fabric import as_view
    from repro.placement.persist import PersistentTier

    store = (pathlib.Path(__file__).resolve().parent.parent
             / "benchmarks" / "results" / "persist_store_demo")
    rng = np.random.default_rng(seed)
    preamble = rng.integers(1, cfg.vocab_size, 48).tolist()

    def boot(tier):
        pool = BwapPagePool(cfg, [
            MemoryDomain("hbm_local", 48, 819.0, True),
            MemoryDomain("hbm_peer_1hop", 32, 0.05, False),
            MemoryDomain("host_dram", 48, 0.016, False),
        ], page_size=4, dwp_config=DWPConfig(n=10 ** 6, c=1))
        view = as_view(pool)
        view.fabric.attach_persist(tier)
        sched = RequestScheduler(pool, max_batch=4,
                                 prefill_token_budget=16,
                                 default_max_new=8)
        eng = ServeEngine(cfg, params, pool, scheduler=sched,
                          wall_clock=False, sim_step_s=0.02)
        return pool, view, eng

    tier = PersistentTier(bw_gbps=0.008, capacity_pages=64,
                          directory=store)
    pool, view, eng = boot(tier)
    eng.submit(preamble + rng.integers(1, cfg.vocab_size, 4).tolist())
    pinned = None
    while eng.active or eng.waiting:
        eng.step()
        if pinned is None:           # pin as soon as prefill registers it
            pinned = tier.pin(view, preamble)
    manifest = tier.export_prefixes(view)
    view.fabric.check_invariants()
    print(f"phase 1: served {len(eng.finished)} request(s), pinned the "
          f"{len(preamble)}-token preamble, exported "
          f"{len(manifest['chains'])} chain(s) "
          f"({sum(c['pages'] for c in manifest['chains'])} pages) to "
          f"{store / 'prefix_store'}")

    # "restart": brand-new pool, fabric, and tier — only the disk store
    # survives the teardown
    tier2 = PersistentTier(bw_gbps=0.008, capacity_pages=64,
                           directory=store)
    pool2, view2, eng2 = boot(tier2)
    restored, secs = tier2.import_prefixes(view2)
    eng2.submit(preamble + rng.integers(1, cfg.vocab_size, 4).tolist())
    hits0 = pool2.table.prefix_hit_pages
    eng2.step()
    hits = pool2.table.prefix_hit_pages - hits0
    while eng2.active or eng2.waiting:
        eng2.step()
    view2.fabric.check_invariants()
    print(f"after restart: {restored} pages re-imported in "
          f"{secs * 1e3:.2f} ms (Eq.-1 tier row); the first request "
          f"matched {hits} pages from the restored trie — prefill skipped "
          f"the whole preamble, computing "
          f"{eng2.prefill_tokens_computed} forward tokens instead of "
          f"{len(preamble) + 4}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--kind", default="bursty",
                    choices=["poisson", "bursty", "heavy_tail",
                             "domain_skew", "hot_prefix"])
    ap.add_argument("--policy", default="bwap_dwp",
                    help="placement policy (see repro.placement.policy); "
                         "'coda' adds compute-follows-data execution: "
                         "per-domain micro-batch decode + heat-driven "
                         "re-homing of hot shared pages (DESIGN.md §11)")
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="shared system-prompt length (0 disables)")
    ap.add_argument("--spec", type=int, default=0, metavar="K",
                    help="speculative decode with a K-token prompt-lookup "
                         "drafter (0 disables; outputs stay token-identical "
                         "to greedy)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--restart-demo", action="store_true",
                    help="run the persistence-tier restart walkthrough "
                         "(prefix store export -> teardown -> re-import)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="dump a Chrome/Perfetto trace-event JSON of the "
                         "run (load it in ui.perfetto.dev)")
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.restart_demo:
        restart_demo(cfg, params, args.seed)
        return

    # slow-domain bandwidths scaled into the engine-latency range so the
    # Eq.-1 terms (KV reads, swap transfers) are visible on a CPU host
    domains = [
        MemoryDomain("hbm_local", 12, 819.0, True),
        MemoryDomain("hbm_peer_1hop", 12, 0.05, False),
        MemoryDomain("hbm_pod1_dci", 12, 0.0125, False),
        MemoryDomain("host_dram", 64, 0.016, False),
    ]
    pool = BwapPagePool(cfg, domains, page_size=8,
                        dwp_config=DWPConfig(n=6, c=1),
                        policy=args.policy)
    swap = KVSwapManager(pool, placement="bwap_canonical",
                         reserve_fraction=0.95)
    sched = RequestScheduler(
        pool, max_batch=6, prefill_token_budget=32,
        classes=[PriorityClass("interactive", 2, SloSpec(ttft_s=0.5,
                                                         tpot_s=0.1)),
                 PriorityClass("batch", 0)],
        default_class="batch", default_max_new=args.new, swap=swap)
    # virtual clock on the Eq.-1 analytic terms + a 20 ms compute stand-in:
    # wall time on a CPU host is dominated by jit compiles and would drown
    # the SLO numbers in noise
    drafter = None
    if args.spec > 0:
        from repro.serve.spec import PromptLookupDrafter
        drafter = PromptLookupDrafter(max_tokens=args.spec, max_ngram=3)
    eng = ServeEngine(cfg, params, pool, scheduler=sched, wall_clock=False,
                      sim_step_s=0.02, drafter=drafter)
    obs = None
    if args.trace_out or eng.rehome:
        # --policy coda needs the observatory's heat map to rank re-home
        # candidates; tracing stays opt-in via --trace-out
        from repro.obs import Observatory
        obs = Observatory(pool, drift=False,
                          tracer=bool(args.trace_out))

    trace = generate(WorkloadSpec(
        kind=args.kind, num_requests=args.requests,
        mean_interarrival_s=0.01, prompt_mean=14, prompt_max=40,
        max_new=args.new, vocab_size=cfg.vocab_size,
        class_mix=(("interactive", 0.3), ("batch", 0.7)), seed=args.seed,
        prefix_len=args.prefix_len, prefix_groups=2, prefix_frac=0.7))
    # total_kv_pages counts *logical* pages (every request's full view);
    # with prefix reuse the trie maps identical prompt prefixes onto the
    # same physical pages, so the physical oversubscription is lower —
    # track the peak physical footprint and report both
    footprint = total_kv_pages(trace, pool.page_size)
    print(f"workload: {len(trace)} requests ({args.kind}), logical KV "
          f"footprint {footprint} pages vs hbm_local "
          f"{domains[0].num_pages} "
          f"(oversubscription x{footprint / domains[0].num_pages:.1f}); "
          f"unreserved pool {pool.free_count()}, swap slots "
          f"{swap.reserved_total}")
    for t in trace:
        eng.submit(t.prompt, cls=t.cls, max_new=t.max_new,
                   arrival_s=t.arrival_s)

    step = 0
    peak_phys = peak_logical = multi_launch_steps = 0
    while eng.active or eng.waiting:
        info = eng.step()
        step += 1
        if info.get("launches", 0) > 1:
            multi_launch_steps += 1
        pt = info.get("pagetable", {})
        peak_phys = max(peak_phys, pt.get("physical_pages", 0))
        peak_logical = max(peak_logical, pt.get("logical_pages", 0))
        if step % 8 == 0 or not (eng.active or eng.waiting):
            occ = " ".join(f"{k}={v:.0%}"
                           for k, v in info.get("occupancy", {}).items())
            print(f"step {step:3d} active={info['active']} "
                  f"swapped={info.get('swapped', 0)} "
                  f"lat={info.get('latency', 0) * 1e3:6.1f} ms "
                  f"dwp={info.get('dwp', 0):.1f} "
                  f"shared={pt.get('shared_pages', 0)}  {occ}")
        if step > 800:
            break

    tel = pool.telemetry.snapshot()
    slo = sched.slo.summary(sched.now)
    pt = pool.table.stats()
    print(f"\nfinished {len(eng.finished)}/{len(trace)} sequences in "
          f"{sched.now:.2f} virtual s; swaps {tel['swap_outs']} out / "
          f"{tel['swap_ins']} in ({tel['swap_seconds'] * 1e3:.0f} ms "
          f"transfer); goodput {slo['goodput_tok_s']:.0f} good tok/s")
    if args.spec > 0:
        sp = tel["spec"]
        print(f"speculation: {eng.tokens_emitted} tokens in "
              f"{eng.decode_steps} decode steps "
              f"({eng.tokens_emitted - eng.decode_steps} steps saved); "
              f"acceptance {sp['acceptance_rate']:.0%} "
              f"({sp['accepted']}/{sp['drafted']} drafted)")
    print(f"KV footprint: peak {peak_logical} logical / {peak_phys} "
          f"physical pages "
          f"(x{peak_logical / max(peak_phys, 1):.2f} sharing; "
          f"physical oversubscription vs hbm_local "
          f"x{peak_phys / domains[0].num_pages:.1f}); "
          f"prefix hits {pt['prefix_hit_pages']} pages, "
          f"cow faults {pt['cow_faults']}, prefill fwd tokens "
          f"{eng.prefill_tokens_computed}")
    for cls, row in slo["classes"].items():
        print(f"  {cls:12s} done {row['completed']:3d}/{row['submitted']:3d}"
              f"  good {row['good']:3d}  ttft {row['ttft_mean_s'] * 1e3:7.1f}"
              f" ms (p95 {row['ttft_p95_s'] * 1e3:7.1f})  tpot "
              f"{row['tpot_mean_s'] * 1e3:6.1f} ms  preempted "
              f"{row['preemptions']}")
    if eng.rehome or sched.micro_batch:
        print(f"compute-follows-data ({args.policy}): "
              f"{multi_launch_steps}/{step} steps ran per-domain "
              f"micro-batch launches; {eng.rehomed_pages} hot shared "
              f"pages re-homed into fast domains")
    for s in eng.finished[:3]:
        print(f"  seq {s.sid} [{s.cls}]: {s.tokens[:5]}... -> "
              f"{s.tokens[s.prompt_len:s.prompt_len + 5]}...")
    if obs is not None and obs.tracer is not None:
        path = obs.tracer.export(args.trace_out)
        spans = {n: len(obs.tracer.spans(n))
                 for n in ("prefill", "decode", "swap_out", "swap_in")}
        print(f"\ntrace: {len(obs.tracer.events)} events -> {path} "
              f"({' '.join(f'{k}={v}' for k, v in spans.items())}); "
              f"open ui.perfetto.dev -> 'Open trace file' to view "
              f"(one track per request, virtual-clock timestamps)")


if __name__ == "__main__":
    main()
