"""End-to-end serving driver: batched requests through the BWAP page pool.

Continuous batching + paged attention + weighted page placement across
memory domains + online DWP tuning from measured decode latencies.

    PYTHONPATH=src python examples/serve_paged.py [--requests 6] [--new 24]
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BwapPagePool, MemoryDomain


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    domains = [
        MemoryDomain("hbm_local", 96, 819.0, True),
        MemoryDomain("hbm_peer_1hop", 64, 50.0, False),
        MemoryDomain("hbm_pod1_dci", 48, 12.5, False),
        MemoryDomain("host_dram", 256, 16.0, False),
    ]
    pool = BwapPagePool(cfg, domains, page_size=8,
                        dwp_config=DWPConfig(n=6, c=1))
    eng = ServeEngine(cfg, params, pool, max_batch=4, max_new=args.new)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab_size, 12).tolist())

    print(f"canonical domain weights: "
          + ", ".join(f"{d.name}={w:.3f}"
                      for d, w in zip(domains, pool.canonical)))
    step = 0
    while eng.active or eng.waiting:
        info = eng.step()
        step += 1
        if step % 8 == 0 or not eng.active:
            occ = " ".join(f"{k}={v:.0%}"
                           for k, v in info.get("occupancy", {}).items())
            print(f"step {step:3d} active={info['active']} "
                  f"lat={info.get('latency', 0) * 1e3:6.1f} ms "
                  f"dwp={info.get('dwp', 0):.1f}  {occ}")
        if step > 400:
            break
    print(f"\nfinished {len(eng.finished)} sequences; "
          f"mean latency {np.mean(eng.latencies) * 1e3:.1f} ms; "
          f"final DWP {pool.tuner.dwp:.1f}")
    for s in eng.finished[:3]:
        print(f"  seq {s.sid}: {s.tokens[:6]}... -> "
              f"{s.tokens[s.prompt_len:s.prompt_len + 6]}...")


if __name__ == "__main__":
    main()
