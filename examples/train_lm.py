"""End-to-end training driver on CPU: reduced LM, synthetic pipeline,
checkpoint/restart, straggler-aware data routing.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200   # resumes!

Scale knobs: --d-model/--layers grow toward the ~100M-param configuration
(--preset 100m) when you have more than one CPU core to spare.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import BwapDataRouter, ShardedTokenDataset
from repro.models.lm import LM
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--ckpt", default="/tmp/bwap_train_ckpt")
    args = ap.parse_args()

    base = registry.get_smoke_config("qwen2-0.5b")
    if args.preset == "100m":
        cfg = dataclasses.replace(base, num_layers=12, d_model=768,
                                  num_heads=12, num_kv_heads=4, d_ff=2048,
                                  vocab_size=32000)
    else:
        cfg = dataclasses.replace(base, num_layers=args.layers,
                                  d_model=args.d_model,
                                  num_heads=4, num_kv_heads=2,
                                  d_ff=4 * args.d_model, vocab_size=4096)
    model = LM(cfg)
    n = cfg.param_counts()["total"]
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"({n / 1e6:.1f}M params)")

    # BWAP-weighted data routing over 4 simulated hosts
    ds = ShardedTokenDataset(cfg.vocab_size, args.seq, num_shards=16, seed=0)
    router = BwapDataRouter(16, host_bws=[1.0, 1.0, 0.8, 0.6])

    def batch_fn(step):
        shards = router.shards_of(step % 4)
        shard = int(shards[step % max(len(shards), 1)]) if len(shards) else 0
        return {"tokens": jnp.asarray(ds.batch(shard, step, args.batch))}

    trainer = Trainer(model, OptConfig(lr=3e-3, warmup_steps=20,
                                       total_steps=args.steps),
                      LoopConfig(total_steps=args.steps, ckpt_every=50,
                                 log_every=20),
                      args.ckpt, batch_fn)
    step0, *_ = start = trainer.restore_or_init()
    if step0:
        print(f"resumed from checkpoint at step {step0}")
    step, params, opt_state, metrics = trainer.run(start)
    print(f"done at step {step}; final loss {float(metrics['loss']):.4f} "
          f"(uniform-random baseline would be "
          f"{np.log(cfg.vocab_size):.2f})")
    print(f"mean step time {np.mean(trainer.step_times) * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
