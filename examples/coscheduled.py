"""Co-scheduled serving (paper §III-B3 as a runtime): two ServeEngine
tenants share one machine's memory domains through the placement arbiter.

Tenant A is high-priority (claims the fastest domain as its home); tenant B
is best-effort and memory-intensive. The arbiter partitions every domain's
pages between them and drives B with the two-stage co-scheduled DWP search:
stage 1 raises B's DWP — migrating B's pages *out* of A's home domain —
while A's latency stream keeps improving, freezing a lower bound when A
stabilises; stage 2 optimizes B's own latency without ever dropping below
the bound. When B leaves, the arbiter rebalances its capacity onto A (live
pool rebuilt in one batched copy, page tables remapped).

The CPU host has no real memory-domain asymmetry, so — exactly like
ServeEngine's own latency signal — the tuners are fed the analytic Eq.-1
read time plus the arbiter's cross-tenant interference term.

    PYTHONPATH=src python examples/coscheduled.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.placement.arbiter import DomainArbiter, DomainSpec, Priority
from repro.serve.engine import ServeEngine

INTERFERENCE_SCALE = 2e5   # maps resident-byte contention to the ms scale
A_BASE = 0.020             # A's isolated per-step stall baseline
A_HEADROOM = 0.25          # fraction of B's pages on A's home that A's
                           # controllers absorb: below it A is compute-bound
                           # and stops improving (the §III-B3 saturation
                           # that freezes the stage-1 bound)


def stall_a(arb):
    """A's stall stream: rises with the *fraction* of B's resident pages
    sitting on A's home domain (stationary under B's load growth),
    saturating at A's controller headroom."""
    used_b = arb.tenants["B"].pool.used_pages()
    frac_on_a = used_b[arb.tenants["A"].home[0]] / max(used_b.sum(), 1)
    return A_BASE + 0.5 * max(0.0, float(frac_on_a) - A_HEADROOM)


def stall_b(arb, eng_b):
    """B's stall stream: Eq.-1 read time of its active pages plus the
    interference it sees on its own home domain."""
    pages = [p for s in eng_b.active for p in s.pages]
    return (arb.tenants["B"].pool.expected_read_time(pages)
            + arb.interference("B", scale=INTERFERENCE_SCALE))


def main():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    params = LM(cfg).init(jax.random.PRNGKey(0))

    specs = [
        DomainSpec("hbm_local", 192, 819.0),
        DomainSpec("hbm_peer_1hop", 160, 50.0),
        DomainSpec("hbm_pod1_dci", 96, 12.5),
        DomainSpec("host_dram", 256, 16.0),
    ]
    arb = DomainArbiter(specs, page_size=4)

    ten_a = arb.register("A", cfg, priority=Priority.HIGH, share=0.5)
    ten_b = arb.register(
        "B", cfg, priority=Priority.BEST_EFFORT, share=0.5,
        dwp_config=DWPConfig(n=6, c=1, rel_tolerance=0.0))
    eng_a = ServeEngine(cfg, params, ten_a.pool, max_batch=3, max_new=20)
    eng_b = ServeEngine(cfg, params, ten_b.pool, max_batch=4, max_new=20)
    arb.attach_engine("A", eng_a)
    arb.attach_engine("B", eng_b)

    print("tenants:", {n: f"{s['priority']} home={s['home']} "
                          f"quota={s['quota_pages']}p"
                       for n, s in arb.stats().items()})

    rng = np.random.default_rng(0)
    for _ in range(3):
        eng_a.submit(rng.integers(1, cfg.vocab_size, 8).tolist())
    for _ in range(4):
        eng_b.submit(rng.integers(1, cfg.vocab_size, 10).tolist())

    print("\ntwo-stage co-scheduled DWP search (B best-effort vs A "
          "high-priority):")
    step = 0
    while step < 200 and not ten_b.cotuner.done:
        # keep both engines saturated so placement pressure stays steady
        while len(eng_a.active) + len(eng_a.waiting) < 3:
            eng_a.submit(rng.integers(1, cfg.vocab_size, 8).tolist())
        while len(eng_b.active) + len(eng_b.waiting) < 4:
            eng_b.submit(rng.integers(1, cfg.vocab_size, 10).tolist())
        eng_a.step()
        eng_b.step()
        step += 1
        if step <= 25:
            continue   # warm-up: let continuous batching reach steady state
        arb.observe("A", stall_a(arb))
        arb.observe("B", stall_b(arb, eng_b))
        if step % 8 == 0:
            b_on_a = int(ten_b.pool.used_pages()[ten_a.home[0]])
            print(f"  step {step:3d} stage={ten_b.cotuner.stage} "
                  f"dwp={ten_b.dwp:.1f} "
                  f"bound={ten_b.cotuner.dwp_lower_bound:.1f} "
                  f"B-pages-on-A-home={b_on_a}")

    print(f"\nstage-1 lower bound on B's DWP: "
          f"{ten_b.cotuner.dwp_lower_bound:.1f} (protects A)")
    print(f"final DWP for B: {ten_b.dwp:.1f} "
          f"(search {'done' if ten_b.cotuner.done else 'still running'})")
    tel_b = ten_b.pool.telemetry.snapshot()
    print(f"B migrations: {tel_b['executed_moves']} pages, "
          f"{tel_b['bytes_moved'] / 1e6:.2f} MB moved")
    for name, d in tel_b["domains"].items():
        print(f"  {name:14s} allocs={d['allocs']:4d} in={d['migr_in']:4d} "
              f"out={d['migr_out']:4d}")

    # -- tenant B leaves: arbiter rebalances its capacity onto A ------------
    quota_before = int(ten_a.quotas.sum())
    grants = arb.unregister("B")
    print(f"\nB left; A's quota {quota_before} -> "
          f"{int(ten_a.quotas.sum())} pages "
          f"(granted per domain: {grants['A'].tolist()})")
    for _ in range(6):
        eng_a.step()   # A keeps serving on the rebalanced pool
    done_a = len(eng_a.finished)
    print(f"A finished {done_a} sequences end-to-end; pool occupancy "
          + " ".join(f"{k}={v:.0%}" for k, v in ten_a.pool.occupancy().items()))


if __name__ == "__main__":
    main()
