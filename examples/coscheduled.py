"""Co-scheduled placement (paper §III-B3): a best-effort memory-intensive
app B spills pages onto the nodes of a high-priority app A without
degrading A — the two-stage DWP search in action.

    PYTHONPATH=src python examples/coscheduled.py
"""

import numpy as np

from repro.core import interleave, topology
from repro.core.canonical import CanonicalTuner
from repro.core.dwp import CoScheduledTuner, DWPConfig
from repro.core.simulator import PAPER_WORKLOADS, NumaSimulator

mach = topology.machine_a()
sim = NumaSimulator(mach)
workers_b = [0, 1]                     # best-effort app B lives here
workers_a = [2, 3, 4, 5, 6, 7]         # high-priority app A

app_b = PAPER_WORKLOADS["SC"]          # memory-intensive
app_a = PAPER_WORKLOADS["FT.C"]        # latency-leaning high-priority

canon = CanonicalTuner(mach).weights_for(workers_b).weights
tuner = CoScheduledTuner(canon, workers_b, num_pages=4096,
                         config=DWPConfig(n=6, c=1, rel_tolerance=0.01))

print("two-stage co-scheduled DWP search:")
period = 0
while not tuner.done and period < 60:
    w_b = interleave.dwp_weights(canon, workers_b, tuner.dwp)
    # A's stall rate rises with B's traffic on A's nodes, but saturates at
    # A's isolated baseline once the interference drops below ~15% of B's
    # pages (A's controllers have headroom; paper §III-B3 scenario).
    b_mass_on_a = w_b[workers_a].sum()
    stall_a = 0.2 + 0.5 * max(0.0, b_mass_on_a - 0.15)
    stall_b = sim.run(app_b, workers_b, "weighted", w_b,
                      noise=0.01).stall_rate
    for _ in range(tuner.cfg.n):
        tuner.record(stall_a, stall_b)
    period += 1
    print(f"  period {period:2d} stage={tuner.stage} dwp={tuner.dwp:.1f} "
          f"B-mass-on-A={b_mass_on_a:.2f}")

print(f"\nstage-1 lower bound on B's DWP: {tuner.dwp_lower_bound:.1f} "
      f"(protects A)")
print(f"final DWP for B: {tuner.dwp:.1f}")
w_final = interleave.dwp_weights(canon, workers_b, tuner.dwp)
t_b = sim.run(app_b, workers_b, 'weighted', w_final).time
t_b_uw = sim.run(app_b, workers_b, 'uniform_workers').time
print(f"B speedup vs uniform-workers: {t_b_uw / t_b:.2f}x, with B's pages "
      f"on A's nodes capped at {w_final[workers_a].sum():.0%}")
