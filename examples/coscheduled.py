"""Co-scheduled serving over one memory fabric (paper §III-B3 + DESIGN.md §8):
two ServeEngine tenants share one machine's memory domains as views of a
single MemoryFabric, brokered by the placement arbiter.

Tenant A is high-priority (claims the fastest domain as its home); tenant B
is best-effort, bursty, and quota-starved. The run demonstrates the two
cross-tenant features the fabric API exists for:

- **Read-only prefix tier** — both tenants serve prompts that open with the
  same system preamble; A's prefilled pages register in the shared trie and
  B's requests map them straight into their views (shared physical pages
  across tenants > 0, physical footprint < logical).
- **Swap-slot loans** — B's own swap reservation is 2 slots, far below
  what preempting its bulk batch for a mid-run interactive burst needs;
  the fabric loans it A's idle reserved slots (grant), B parks preempted
  KV in them (use), and A reclaims them afterwards (reclaim, Eq.-1
  accounted). On isolated partitions the same burst cannot preempt —
  interactive requests wait in queue and B's makespan stretches.

Both features are placement-only: the same workload replayed on *isolated*
partitions (sharing and loans disabled) produces token-identical outputs —
asserted at the end — it just burns more physical pages and more waiting.

The arbiter still drives B with the two-stage co-scheduled DWP search
(stage 1 raises B's DWP while A's latency stream keeps improving, stage 2
optimizes B's own latency above the frozen bound); cycle moves re-home B's
live pages through the view's assignment-change subscription. When B
leaves, its quota redistributes to A as pure ledger arithmetic — no pool
rebuild, no page-id remapping.

    PYTHONPATH=src python examples/coscheduled.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import registry
from repro.core.dwp import DWPConfig
from repro.models.lm import LM
from repro.placement.arbiter import DomainArbiter, DomainSpec, Priority
from repro.scheduler import (KVSwapManager, PriorityClass,
                             RequestScheduler)
from repro.serve.engine import ServeEngine

INTERFERENCE_SCALE = 2e5   # maps resident-byte contention to the ms scale
A_BASE = 0.020             # A's isolated per-step stall baseline
A_HEADROOM = 0.25          # fraction of B's pages on A's home that A's
                           # controllers absorb: below it A is compute-bound
                           # and stops improving (the §III-B3 saturation
                           # that freezes the stage-1 bound)

SPECS = [
    DomainSpec("hbm_local", 192, 819.0),
    DomainSpec("hbm_peer_1hop", 160, 50.0),
    DomainSpec("hbm_pod1_dci", 96, 12.5),
    DomainSpec("host_dram", 256, 16.0),
]


def stall_a(arb):
    """A's stall stream: rises with the *fraction* of B's resident pages
    sitting on A's home domain (stationary under B's load growth),
    saturating at A's controller headroom."""
    used_b = arb.tenants["B"].view.used_pages()
    frac_on_a = used_b[arb.tenants["A"].home[0]] / max(used_b.sum(), 1)
    return A_BASE + 0.5 * max(0.0, float(frac_on_a) - A_HEADROOM)


def stall_b(arb, eng_b):
    """B's stall stream: Eq.-1 read time of its active pages plus the
    interference it sees on its own home domain."""
    pages = [p for s in eng_b.active for p in s.pages]
    return (arb.tenants["B"].view.expected_read_time(pages)
            + arb.interference("B", scale=INTERFERENCE_SCALE))


def build(cfg, params, shared: bool):
    """Two tenants over one fabric. ``shared=False`` keeps the same quotas
    but disables the prefix tier and the loan broker — isolated
    partitions, the baseline the fabric run must match token-for-token."""
    arb = DomainArbiter(SPECS, page_size=4)
    ta = arb.register("A", cfg, priority=Priority.HIGH, share=0.5,
                      share_prefix=shared)
    tb = arb.register("B", cfg, priority=Priority.BEST_EFFORT, share=0.07,
                      share_prefix=shared,
                      dwp_config=DWPConfig(n=6, c=1, rel_tolerance=0.0))
    swap_a = KVSwapManager(ta.view, reserve_fraction=0.5,
                           lend=shared, borrow=shared)
    # B owns just 2 parking slots: preempting one bulk victim (~5
    # exclusive pages) already needs the loan broker
    swap_b = KVSwapManager(tb.view, reserve_pages={"host_dram": 2},
                           lend=shared, borrow=shared)
    eng_a = ServeEngine(cfg, params, ta.view, wall_clock=False,
                        sim_step_s=0.01,
                        scheduler=RequestScheduler(
                            ta.view, max_batch=3, default_max_new=16,
                            swap=swap_a))
    # within B: an "interactive" class above the bulk default — its
    # mid-run burst is what forces preemption (and therefore parking)
    eng_b = ServeEngine(cfg, params, tb.view, wall_clock=False,
                        sim_step_s=0.01,
                        scheduler=RequestScheduler(
                            tb.view, max_batch=6, default_max_new=16,
                            swap=swap_b,
                            classes=[PriorityClass("B_hi", 5)]))
    return arb, (ta, eng_a, swap_a), (tb, eng_b, swap_b)


def workload(cfg, rng):
    """Fixed trace: a common 8-token system preamble (2 fabric pages),
    then per-request suffixes. A serves 3 requests; B a 6-request bulk
    batch plus a 3-request interactive burst injected mid-run."""
    preamble = rng.integers(1, cfg.vocab_size, 8).tolist()
    a_prompts = [preamble + rng.integers(1, cfg.vocab_size, 6).tolist()
                 for _ in range(3)]
    b_bulk = [preamble + rng.integers(1, cfg.vocab_size, 4).tolist()
              for _ in range(6)]
    b_hi = [preamble + rng.integers(1, cfg.vocab_size, 2).tolist()
            for _ in range(3)]
    return a_prompts, b_bulk, b_hi


def run(cfg, params, shared: bool, verbose: bool) -> dict:
    arb, (ta, eng_a, _), (tb, eng_b, swap_b) = build(cfg, params, shared)
    a_prompts, b_bulk, b_hi = workload(cfg, np.random.default_rng(0))
    for p in a_prompts:
        eng_a.submit(list(p))
    for p in b_bulk:
        eng_b.submit(list(p))

    peak_shared = peak_borrowed_parked = step = 0
    while (eng_a.active or eng_a.waiting or eng_b.active
           or eng_b.waiting) and step < 400:
        if step == 12:                 # the interactive burst arrives
            for p in b_hi:
                eng_b.submit(list(p), cls="B_hi", max_new=8)
        if eng_a.active or eng_a.waiting:
            eng_a.step()
        if eng_b.active or eng_b.waiting:
            eng_b.step()
        step += 1
        arb.observe("A", stall_a(arb))
        arb.observe("B", stall_b(arb, eng_b))
        peak_shared = max(peak_shared, arb.fabric.cross_shared_pages())
        peak_borrowed_parked = max(
            peak_borrowed_parked,
            sum(1 for p in swap_b._out if p in swap_b._borrowed))
        if verbose and step % 10 == 0:
            b_on_a = int(tb.view.used_pages()[ta.home[0]])
            print(f"  step {step:3d} stage={tb.cotuner.stage} "
                  f"dwp={tb.dwp:.1f} "
                  f"bound={tb.cotuner.dwp_lower_bound:.1f} "
                  f"xshared={arb.fabric.cross_shared_pages():3d}p "
                  f"borrowed-parked={peak_borrowed_parked:2d} "
                  f"B-pages-on-A-home={b_on_a}")

    # loan cycle epilogue: A reclaims everything it lent out
    outstanding = sum(len(ln.slots) for ln in arb.fabric.loans
                      if ln.lender == "A")
    reclaimed, secs = ta.view.recall_loans(outstanding) \
        if outstanding else (0, 0.0)
    tokens = {
        "A": [list(s.tokens) for s in sorted(eng_a.finished,
                                             key=lambda s: s.sid)],
        "B": [list(s.tokens) for s in sorted(eng_b.finished,
                                             key=lambda s: s.sid)],
    }
    slo_b = eng_b.scheduler.slo.summary(eng_b.scheduler.now)["classes"]
    arb.fabric.check_invariants()
    return {
        "arb": arb, "ta": ta, "tb": tb, "eng_a": eng_a, "eng_b": eng_b,
        "tokens": tokens, "steps": step, "peak_shared": peak_shared,
        "peak_borrowed_parked": peak_borrowed_parked,
        "reclaimed": reclaimed, "reclaim_s": secs,
        "loans": [dataclasses.asdict(ln) for ln in arb.fabric.loans],
        "b_makespan": eng_b.scheduler.now,
        "b_hi_ttft": slo_b["B_hi"]["ttft_mean_s"],
        "b_hi_preempts_bulk": slo_b["B"]["preemptions"],
    }


def main():
    cfg = registry.get_smoke_config("qwen2-0.5b")
    cfg = dataclasses.replace(cfg, num_layers=2, compute_dtype="float32")
    params = LM(cfg).init(jax.random.PRNGKey(0))

    print("one fabric, two tenant views (A high-priority, B best-effort "
          "burst):")
    fab = run(cfg, params, shared=True, verbose=True)
    ta, tb, arb = fab["ta"], fab["tb"], fab["arb"]

    print(f"\ncross-tenant prefix tier: peak {fab['peak_shared']} physical "
          f"pages shared across tenants "
          f"(trie hits {arb.fabric.table.stats()['prefix_hit_pages']}p)")
    for ln in fab["loans"]:
        print(f"swap-slot loan {ln['lender']}->{ln['borrower']}: "
              f"granted {ln['granted']} slots, peak parked-in-borrowed "
              f"{fab['peak_borrowed_parked']}, reclaimed {ln['reclaimed']} "
              f"({ln['reclaim_seconds'] * 1e3:.1f} ms Eq.-1 vacate), "
              f"outstanding {len(ln['slots'])}")
    print(f"interactive burst: {fab['b_hi_preempts_bulk']} bulk "
          f"preemptions into borrowed slots, B_hi mean TTFT "
          f"{fab['b_hi_ttft'] * 1e3:.0f} ms")
    print(f"stage-1 lower bound on B's DWP: "
          f"{tb.cotuner.dwp_lower_bound:.1f} (protects A); "
          f"final DWP {tb.dwp:.1f} "
          f"({'done' if tb.cotuner.done else 'still searching'})")

    print("\nreplay on isolated partitions (no prefix tier, no loans):")
    iso = run(cfg, params, shared=False, verbose=False)
    identical = fab["tokens"] == iso["tokens"]
    print(f"  isolated: 0 shared pages (peak {iso['peak_shared']}), "
          f"loans {len(iso['loans'])}, 0 preemptions "
          f"({iso['b_hi_preempts_bulk']}): the burst waits — B_hi mean "
          f"TTFT {iso['b_hi_ttft'] * 1e3:.0f} ms vs fabric "
          f"{fab['b_hi_ttft'] * 1e3:.0f} ms")
    print(f"  token-identical outputs fabric vs isolated: {identical}")
    assert identical, "fabric sharing/loans must not change tokens"
    assert fab["peak_shared"] > 0, "no cross-tenant sharing demonstrated"
    assert any(ln["granted"] > 0 for ln in fab["loans"]), \
        "no swap-slot loan demonstrated"

    # -- tenant B leaves: quota redistributes as ledger arithmetic ----------
    quota_before = int(ta.quotas.sum())
    grants = arb.unregister("B")
    print(f"\nB left; A's quota {quota_before} -> "
          f"{int(ta.quotas.sum())} pages "
          f"(granted per domain: {grants['A'].tolist()}; no pool rebuild, "
          f"no page remapping)")
    eng_a = fab["eng_a"]
    eng_a.submit(np.random.default_rng(1).integers(
        1, cfg.vocab_size, 8).tolist())
    while eng_a.active or eng_a.waiting:
        eng_a.step()
    print(f"A finished {len(eng_a.finished)} sequences end-to-end; "
          "occupancy "
          + " ".join(f"{k}={v:.0%}" for k, v in ta.view.occupancy().items()))


if __name__ == "__main__":
    main()
