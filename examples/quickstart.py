"""Quickstart: the BWAP core library in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import interleave, topology
from repro.core.canonical import CanonicalTuner
from repro.core.dwp import DWPConfig, DWPTuner
from repro.core.simulator import PAPER_WORKLOADS, NumaSimulator

# 1. A NUMA machine (the paper's 8-node Opteron, reconstructed) ------------
mach = topology.machine_a()
print(f"{mach.name}: {mach.num_nodes} nodes, "
      f"local bw {mach.local_bw(0):.0f} GB/s, "
      f"amplitude {mach.bw.max() / mach.bw[mach.bw > 0].min():.1f}x")

# 2. Canonical weights for a 2-node worker set (Eq. 5) ---------------------
tuner = CanonicalTuner(mach)
entry = tuner.weights_for([0, 1])
print("\ncanonical weights (w_i ∝ minbw_i):")
for i, w in enumerate(entry.weights):
    tag = "worker" if i in (0, 1) else "      "
    print(f"  node {i} {tag}  w={w:.3f}  minbw={entry.minbw[i]:.2f} GB/s")

# 3. Weighted page interleaving (Alg. 1) -----------------------------------
pages = interleave.weighted_interleave(4096, entry.weights)
frac = interleave.page_fractions(pages, mach.num_nodes)
print(f"\nAlg.1 page fractions match weights: "
      f"max err {np.abs(frac - entry.weights).max():.4f}")

# 4. Online DWP tuning against the simulator -------------------------------
sim = NumaSimulator(mach)
app = PAPER_WORKLOADS["SC"]
dwp_tuner = DWPTuner(entry.weights, workers=[0, 1], num_pages=4096,
                     config=DWPConfig(n=8, c=2))
while not dwp_tuner.done:
    w = interleave.dwp_weights(entry.weights, [0, 1], dwp_tuner.dwp)
    stall = sim.run(app, [0, 1], "weighted", w, noise=0.01).stall_rate
    dwp_tuner.record(stall)
print(f"\nDWP tuner converged at DWP={dwp_tuner.dwp:.1f} "
      f"after {len(dwp_tuner.history)} periods")

# 5. The punchline: BWAP vs the usual suspects ------------------------------
w_final = interleave.dwp_weights(entry.weights, [0, 1], dwp_tuner.dwp)
t_bwap = sim.run(app, [0, 1], "weighted", w_final).time
for pol in ("first_touch", "uniform_workers", "uniform_all"):
    t = sim.run(app, [0, 1], pol).time
    print(f"  {pol:16s} {t / t_bwap:5.2f}x slower than BWAP")
